//! Training stage graph with run caching — a crate-internal
//! implementation detail of [`crate::session`] (DESIGN.md §2), only
//! compiled with the `xla` feature (training needs the AOT train-step
//! artifact; everything downstream of the folded tensors is
//! backend-agnostic).
//!
//! Stage graph: train -> export(fold). Trained weights cache in
//! `runs/` so sessions compose without retraining. The hardware solve
//! lives in `crate::session::solver`; accuracy evaluation and F_MAC
//! extraction go through the [`crate::backend::InferenceBackend`] the
//! session selected. External consumers go through `DesignSession` —
//! this type is not part of the public API.

use anyhow::Result;

use super::config::ExperimentConfig;
use super::store::{NamedTensor, Store};
use super::trainer::Trainer;
use crate::data::synth::Dataset;
use crate::data::{Loader, Split};
use crate::runtime::{to_f32, Runtime};

pub struct Pipeline<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ExperimentConfig,
    pub store: Store,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: ExperimentConfig) -> Result<Self> {
        let store = Store::new(&cfg.run_dir)?;
        Ok(Pipeline { rt, cfg, store })
    }

    /// Trained + folded hardware tensors for `ds` (cached in the run
    /// store as host tensors — the session hands them to whichever
    /// backend evaluates them).
    pub fn ensure_folded(&self, ds: Dataset) -> Result<Vec<NamedTensor>> {
        let spec = ds.spec();
        let mi = self.rt.manifest.model(spec.model).clone();
        let cache = crate::session::folded_cache_name(ds);
        if self.store.exists(&cache) {
            return self.store.load_tensors(&cache);
        }
        eprintln!(
            "[pipeline] training {} on {} ({} steps)...",
            mi.name,
            spec.name,
            self.cfg.train_steps
        );
        let trainer = Trainer::new(self.rt);
        let mut loader = Loader::new(
            spec.clone(),
            Split::Train,
            mi.train_batch,
            self.cfg.train_limit,
            self.cfg.seed,
        );
        let t0 = std::time::Instant::now();
        let trained = trainer.train(
            &mi.name,
            &mut loader,
            self.cfg.train_steps,
            self.cfg.lr0,
            self.cfg.lr_halve_every,
            self.cfg.seed,
            &mut |step, loss| {
                if step % 50 == 0 {
                    eprintln!("[train {}] step {step} loss {loss:.4}",
                              spec.name);
                }
            },
        )?;
        eprintln!(
            "[pipeline] trained {} in {:.1?} (loss {:.3} -> {:.3})",
            spec.name,
            t0.elapsed(),
            trained.losses.first().unwrap_or(&f32::NAN),
            trained.losses.last().unwrap_or(&f32::NAN)
        );
        let folded = trainer.export(&trained)?;
        // persist loss curve + folded tensors (host form)
        let mut ts = Vec::with_capacity(folded.len());
        for (lit, sig) in folded.iter().zip(
            mi.artifacts["export"].outputs.iter(),
        ) {
            ts.push(NamedTensor {
                name: sig.name.clone(),
                shape: sig.shape.clone(),
                data: to_f32(lit)?,
            });
        }
        self.store.save_tensors(&cache, &ts)?;
        self.store.save_tensors(
            &format!("{}_losses.capt", spec.name),
            &[NamedTensor {
                name: "loss".into(),
                shape: vec![trained.losses.len()],
                data: trained.losses.clone(),
            }],
        )?;
        Ok(ts)
    }
}
