//! End-to-end experiment pipeline with run caching.
//!
//! Stage graph (DESIGN.md §2): train -> export(fold) -> F_MAC -> CapMin
//! window -> capacitor sizing -> Monte-Carlo P_map -> (CapMin-V) ->
//! error-injected evaluation. Trained weights and histograms cache in
//! `runs/` so figure commands compose without retraining.

use anyhow::Result;

use super::config::ExperimentConfig;
use super::evaluator::Evaluator;
use super::histogrammer::Histogrammer;
use super::store::{NamedTensor, Store};
use super::trainer::Trainer;
use crate::analog::capacitor::{CapacitorModel, CapacitorSolver};
use crate::analog::montecarlo::MonteCarlo;
use crate::analog::neuron::SpikeTimeSet;
use crate::analog::params::AnalogParams;
use crate::analog::pmap::Pmap;
use crate::bnn::ErrorModel;
use crate::capmin::{capmin::select_window, capmin_v::capmin_v, Fmac};
use crate::data::synth::Dataset;
use crate::data::{Loader, Split};
use crate::runtime::{lit_f32, to_f32, Runtime};
use crate::util::rng::Rng;

pub struct Pipeline<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ExperimentConfig,
    pub store: Store,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: ExperimentConfig) -> Result<Self> {
        let store = Store::new(&cfg.run_dir)?;
        Ok(Pipeline { rt, cfg, store })
    }

    pub fn params(&self) -> AnalogParams {
        AnalogParams::paper_calibrated().with_sigma(self.cfg.sigma_rel)
    }

    fn folded_cache_name(&self, ds: Dataset) -> String {
        format!("{}_folded.capt", ds.spec().name)
    }

    fn fmac_cache_name(&self, ds: Dataset) -> String {
        format!("{}_fmac.capt", ds.spec().name)
    }

    /// Trained + folded hardware tensors for `ds` (cached).
    pub fn ensure_folded(&self, ds: Dataset) -> Result<Vec<xla::Literal>> {
        let spec = ds.spec();
        let mi = self.rt.manifest.model(spec.model).clone();
        let cache = self.folded_cache_name(ds);
        if self.store.exists(&cache) {
            let ts = self.store.load_tensors(&cache)?;
            return ts
                .iter()
                .map(|t| lit_f32(&t.shape, &t.data))
                .collect::<Result<Vec<_>>>();
        }
        eprintln!(
            "[pipeline] training {} on {} ({} steps)...",
            mi.name,
            spec.name,
            self.cfg.train_steps
        );
        let trainer = Trainer::new(self.rt);
        let mut loader = Loader::new(
            spec.clone(),
            Split::Train,
            mi.train_batch,
            self.cfg.train_limit,
            self.cfg.seed,
        );
        let t0 = std::time::Instant::now();
        let trained = trainer.train(
            &mi.name,
            &mut loader,
            self.cfg.train_steps,
            self.cfg.lr0,
            self.cfg.lr_halve_every,
            self.cfg.seed,
            &mut |step, loss| {
                if step % 50 == 0 {
                    eprintln!("[train {}] step {step} loss {loss:.4}",
                              spec.name);
                }
            },
        )?;
        eprintln!(
            "[pipeline] trained {} in {:.1?} (loss {:.3} -> {:.3})",
            spec.name,
            t0.elapsed(),
            trained.losses.first().unwrap_or(&f32::NAN),
            trained.losses.last().unwrap_or(&f32::NAN)
        );
        let folded = trainer.export(&trained)?;
        // persist loss curve + folded tensors
        let mut ts = Vec::with_capacity(folded.len());
        for (lit, sig) in folded.iter().zip(
            mi.artifacts["export"].outputs.iter(),
        ) {
            ts.push(NamedTensor {
                name: sig.name.clone(),
                shape: sig.shape.clone(),
                data: to_f32(lit)?,
            });
        }
        self.store.save_tensors(&cache, &ts)?;
        self.store.save_tensors(
            &format!("{}_losses.capt", spec.name),
            &[NamedTensor {
                name: "loss".into(),
                shape: vec![trained.losses.len()],
                data: trained.losses.clone(),
            }],
        )?;
        Ok(folded)
    }

    /// F_MAC histograms for `ds` (cached). Also reports clean accuracy.
    pub fn ensure_fmac(&self, ds: Dataset) -> Result<(Vec<Fmac>, Fmac)> {
        let cache = self.fmac_cache_name(ds);
        if self.store.exists(&cache) {
            return self.store.load_fmac(&cache);
        }
        let spec = ds.spec();
        let folded = self.ensure_folded(ds)?;
        eprintln!("[pipeline] extracting F_MAC for {}...", spec.name);
        let hist = Histogrammer::new(self.rt);
        let res = hist.extract_dataset(
            &spec.model.to_string(),
            &folded,
            spec.clone(),
            self.cfg.hist_limit,
            self.cfg.seed ^ 0x48_31u64,
        )?;
        eprintln!(
            "[pipeline] {}: F_MAC over {} samples, clean train-acc {:.3}",
            spec.name, res.n_samples, res.accuracy
        );
        self.store
            .save_fmac(&cache, &res.per_matmul, &res.sum)?;
        Ok((res.per_matmul, res.sum))
    }

    /// The full hardware read-out configuration for one model at CapMin
    /// parameter k: per-matmul windows, one shared capacitor, and the
    /// per-matmul error models the eval artifacts consume.
    ///
    /// The IF-SNN has ONE membrane capacitor, but the spike-time decoder
    /// is digital and per layer: a matmul whose reduction length only
    /// reaches level 9 (grayscale first conv, beta = 9) keeps its own
    /// narrow window instead of being wiped out by the peak-centered
    /// global window. The capacitor is sized by the most demanding
    /// window (largest q_hi) — lower windows have wider time gaps and
    /// ride along for free. `phi > 0` applies CapMin-V merging to each
    /// window (clamped to its size). `sigma = 0` yields the
    /// deterministic Eq.-4 clipping maps.
    pub fn hw_config(
        &self,
        per_fmac: &[Fmac],
        k: usize,
        sigma: f64,
        phi: usize,
    ) -> HwConfig {
        let p = self.params().with_sigma(sigma);
        let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
        let windows: Vec<_> = per_fmac
            .iter()
            .map(|f| select_window(f, k))
            .collect();
        let c = windows
            .iter()
            .map(|w| solver.size_for_window(w.q_lo, w.q_hi))
            .fold(0.0f64, f64::max);
        let mc = MonteCarlo::new(p).with_samples(self.cfg.mc_samples);
        let mut sets = Vec::with_capacity(windows.len());
        let mut ems = Vec::with_capacity(windows.len());
        for (i, w) in windows.iter().enumerate() {
            let base = SpikeTimeSet::new(&p, c, w.levels());
            let levels = if phi > 0 {
                let pmap: Pmap = mc.pmap(
                    &base,
                    &mut Rng::new(self.cfg.seed ^ 0x5107 ^ i as u64),
                );
                let res = capmin_v(pmap, phi.min(w.k - 1));
                res.levels
            } else {
                w.levels()
            };
            let set = SpikeTimeSet::new(&p, c, levels);
            let full = if sigma == 0.0 {
                mc.clean_map(&set)
            } else {
                mc.full_map(
                    &set,
                    &mut Rng::new(self.cfg.seed ^ 0x4D43 ^ (i as u64) << 8),
                )
            };
            ems.push(ErrorModel::from_full(&full));
            sets.push(set);
        }
        HwConfig {
            c,
            windows,
            sets,
            ems,
        }
    }

    pub fn evaluator(&self) -> Evaluator<'rt> {
        Evaluator::new(self.rt, &self.cfg.engine)
    }
}

/// One hardware operating point: shared capacitor + per-matmul read-out.
pub struct HwConfig {
    /// Shared membrane capacitance [F] (sized by the topmost window).
    pub c: f64,
    /// CapMin window per matmul.
    pub windows: Vec<crate::capmin::CapMinResult>,
    /// Spike-time set per matmul (post CapMin-V merging when phi > 0).
    pub sets: Vec<SpikeTimeSet>,
    /// Error model per matmul (the eval artifacts' runtime input).
    pub ems: Vec<ErrorModel>,
}

impl HwConfig {
    /// Guaranteed response time of the slowest window (system latency).
    pub fn grt(&self) -> f64 {
        self.sets
            .iter()
            .map(|s| s.grt())
            .fold(0.0f64, f64::max)
    }

    /// The peak (topmost) window — what drives the capacitor.
    pub fn peak_window(&self) -> &crate::capmin::CapMinResult {
        self.windows
            .iter()
            .max_by_key(|w| w.q_hi)
            .expect("at least one matmul")
    }
}
