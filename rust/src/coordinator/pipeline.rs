//! Training / F_MAC stage graph with run caching — a crate-internal
//! implementation detail of [`crate::session`] (DESIGN.md §2).
//!
//! Stage graph: train -> export(fold) -> F_MAC. Trained weights and
//! histograms cache in `runs/` so sessions compose without retraining.
//! The hardware solve (CapMin window -> capacitor sizing -> Monte-Carlo
//! P_map -> CapMin-V -> error models) lives in
//! `crate::session::solver`; accuracy evaluation in
//! `crate::coordinator::evaluator`. External consumers go through
//! `DesignSession` — this type is not part of the public API.

use anyhow::Result;

use super::config::ExperimentConfig;
use super::histogrammer::Histogrammer;
use super::store::{NamedTensor, Store};
use super::trainer::Trainer;
use crate::capmin::Fmac;
use crate::data::synth::Dataset;
use crate::data::{Loader, Split};
use crate::runtime::{lit_f32, to_f32, Runtime};

pub struct Pipeline<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ExperimentConfig,
    pub store: Store,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: ExperimentConfig) -> Result<Self> {
        let store = Store::new(&cfg.run_dir)?;
        Ok(Pipeline { rt, cfg, store })
    }

    pub(crate) fn folded_cache_name(ds: Dataset) -> String {
        format!("{}_folded.capt", ds.spec().name)
    }

    pub(crate) fn fmac_cache_name(ds: Dataset) -> String {
        format!("{}_fmac.capt", ds.spec().name)
    }

    /// Trained + folded hardware tensors for `ds` (cached).
    pub fn ensure_folded(&self, ds: Dataset) -> Result<Vec<xla::Literal>> {
        let spec = ds.spec();
        let mi = self.rt.manifest.model(spec.model).clone();
        let cache = Self::folded_cache_name(ds);
        if self.store.exists(&cache) {
            let ts = self.store.load_tensors(&cache)?;
            return ts
                .iter()
                .map(|t| lit_f32(&t.shape, &t.data))
                .collect::<Result<Vec<_>>>();
        }
        eprintln!(
            "[pipeline] training {} on {} ({} steps)...",
            mi.name,
            spec.name,
            self.cfg.train_steps
        );
        let trainer = Trainer::new(self.rt);
        let mut loader = Loader::new(
            spec.clone(),
            Split::Train,
            mi.train_batch,
            self.cfg.train_limit,
            self.cfg.seed,
        );
        let t0 = std::time::Instant::now();
        let trained = trainer.train(
            &mi.name,
            &mut loader,
            self.cfg.train_steps,
            self.cfg.lr0,
            self.cfg.lr_halve_every,
            self.cfg.seed,
            &mut |step, loss| {
                if step % 50 == 0 {
                    eprintln!("[train {}] step {step} loss {loss:.4}",
                              spec.name);
                }
            },
        )?;
        eprintln!(
            "[pipeline] trained {} in {:.1?} (loss {:.3} -> {:.3})",
            spec.name,
            t0.elapsed(),
            trained.losses.first().unwrap_or(&f32::NAN),
            trained.losses.last().unwrap_or(&f32::NAN)
        );
        let folded = trainer.export(&trained)?;
        // persist loss curve + folded tensors
        let mut ts = Vec::with_capacity(folded.len());
        for (lit, sig) in folded.iter().zip(
            mi.artifacts["export"].outputs.iter(),
        ) {
            ts.push(NamedTensor {
                name: sig.name.clone(),
                shape: sig.shape.clone(),
                data: to_f32(lit)?,
            });
        }
        self.store.save_tensors(&cache, &ts)?;
        self.store.save_tensors(
            &format!("{}_losses.capt", spec.name),
            &[NamedTensor {
                name: "loss".into(),
                shape: vec![trained.losses.len()],
                data: trained.losses.clone(),
            }],
        )?;
        Ok(folded)
    }

    /// F_MAC histograms for `ds` (cached). Also reports clean accuracy.
    pub fn ensure_fmac(&self, ds: Dataset) -> Result<(Vec<Fmac>, Fmac)> {
        let cache = Self::fmac_cache_name(ds);
        if self.store.exists(&cache) {
            return self.store.load_fmac(&cache);
        }
        let spec = ds.spec();
        let folded = self.ensure_folded(ds)?;
        eprintln!("[pipeline] extracting F_MAC for {}...", spec.name);
        let hist = Histogrammer::new(self.rt);
        let res = hist.extract_dataset(
            &spec.model.to_string(),
            &folded,
            spec.clone(),
            self.cfg.hist_limit,
            self.cfg.seed ^ 0x48_31u64,
        )?;
        eprintln!(
            "[pipeline] {}: F_MAC over {} samples, clean train-acc {:.3}",
            spec.name, res.n_samples, res.accuracy
        );
        self.store
            .save_fmac(&cache, &res.per_matmul, &res.sum)?;
        Ok((res.per_matmul, res.sum))
    }
}
