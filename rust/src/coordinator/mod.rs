//! L3 coordinator: experiment orchestration over the PJRT runtime.

pub mod config;
pub mod evaluator;
pub mod histogrammer;
pub mod pipeline;
pub mod report;
pub mod store;
pub mod trainer;
