//! L3 coordinator: training, F_MAC extraction and evaluation over the
//! PJRT runtime (DESIGN.md §2). External consumers drive these stages
//! through [`crate::session::DesignSession`]; the stage-graph `Pipeline`
//! is crate-internal.

pub mod config;
pub mod evaluator;
pub mod histogrammer;
pub(crate) mod pipeline;
pub mod report;
pub mod store;
pub mod trainer;
