//! L3 coordinator: training, F_MAC extraction and evaluation over the
//! PJRT runtime (DESIGN.md §2). External consumers drive these stages
//! through [`crate::session::DesignSession`]; the stage-graph `Pipeline`
//! is crate-internal. The XLA-bound stages (trainer, histogrammer,
//! evaluator, pipeline) sit behind the `xla` cargo feature — on
//! native-only builds the session evaluates and histograms through
//! [`crate::backend::NativeBackend`] instead.

pub mod config;
#[cfg(feature = "xla")]
pub mod evaluator;
#[cfg(feature = "xla")]
pub mod histogrammer;
#[cfg(feature = "xla")]
pub(crate) mod pipeline;
pub mod report;
pub mod store;
#[cfg(feature = "xla")]
pub mod trainer;
