//! Run store: cached trained weights, F_MAC histograms and result files.
//!
//! Simple self-describing binary tensor format (no serde offline):
//!   magic "CAPT" | u32 n_tensors | per tensor:
//!     u32 name_len | name bytes | u32 ndims | u64 dims[] | f32 data[]
//! plus JSON result files written via util::json.

use std::fs;
use std::io::{Read, Write};
use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

use crate::capmin::{Fmac, N_LEVELS};

const MAGIC: &[u8; 4] = b"CAPT";

#[derive(Clone, Debug, PartialEq)]
pub struct NamedTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

pub struct Store {
    pub dir: PathBuf,
}

impl Store {
    pub fn new(dir: &str) -> Result<Store> {
        fs::create_dir_all(dir)?;
        Ok(Store {
            dir: PathBuf::from(dir),
        })
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    pub fn exists(&self, name: &str) -> bool {
        self.path(name).exists()
    }

    pub fn save_tensors(&self, name: &str, tensors: &[NamedTensor])
        -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for t in tensors {
            let nb = t.name.as_bytes();
            buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            buf.extend_from_slice(nb);
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            debug_assert_eq!(
                t.shape.iter().product::<usize>().max(1),
                t.data.len()
            );
            for &v in &t.data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let tmp = self.path(&format!("{name}.tmp"));
        fs::File::create(&tmp)?.write_all(&buf)?;
        fs::rename(tmp, self.path(name))?;
        Ok(())
    }

    pub fn load_tensors(&self, name: &str) -> Result<Vec<NamedTensor>> {
        let mut bytes = Vec::new();
        fs::File::open(self.path(name))
            .with_context(|| format!("open {name}"))?
            .read_to_end(&mut bytes)?;
        let mut i = 0usize;
        let take = |i: &mut usize, n: usize| -> Result<&[u8]> {
            if *i + n > bytes.len() {
                return Err(anyhow!("truncated store file {name}"));
            }
            let s = &bytes[*i..*i + n];
            *i += n;
            Ok(s)
        };
        if take(&mut i, 4)? != MAGIC {
            return Err(anyhow!("bad magic in {name}"));
        }
        let n = u32::from_le_bytes(take(&mut i, 4)?.try_into()?) as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let nl =
                u32::from_le_bytes(take(&mut i, 4)?.try_into()?) as usize;
            let nm = String::from_utf8(take(&mut i, nl)?.to_vec())?;
            let nd =
                u32::from_le_bytes(take(&mut i, 4)?.try_into()?) as usize;
            let mut shape = Vec::with_capacity(nd);
            for _ in 0..nd {
                shape.push(u64::from_le_bytes(
                    take(&mut i, 8)?.try_into()?,
                ) as usize);
            }
            let len = shape.iter().product::<usize>().max(1);
            let raw = take(&mut i, len * 4)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            out.push(NamedTensor {
                name: nm,
                shape,
                data,
            });
        }
        Ok(out)
    }

    /// F_MAC histograms are stored as one tensor per matmul plus "sum".
    pub fn save_fmac(
        &self,
        name: &str,
        per_matmul: &[Fmac],
        sum: &Fmac,
    ) -> Result<()> {
        let mut ts: Vec<NamedTensor> = per_matmul
            .iter()
            .enumerate()
            .map(|(i, f)| NamedTensor {
                name: format!("mat{i}"),
                shape: vec![N_LEVELS],
                data: f.counts.iter().map(|&c| c as f32).collect(),
            })
            .collect();
        ts.push(NamedTensor {
            name: "sum".into(),
            shape: vec![N_LEVELS],
            data: sum.counts.iter().map(|&c| c as f32).collect(),
        });
        self.save_tensors(name, &ts)
    }

    pub fn load_fmac(&self, name: &str) -> Result<(Vec<Fmac>, Fmac)> {
        let ts = self.load_tensors(name)?;
        let mut per = vec![];
        let mut sum = Fmac::new();
        for t in ts {
            let mut f = Fmac::new();
            for (c, &v) in f.counts.iter_mut().zip(t.data.iter()) {
                *c = v as u64;
            }
            if t.name == "sum" {
                sum = f;
            } else {
                per.push(f);
            }
        }
        Ok((per, sum))
    }

    pub fn save_text(&self, name: &str, text: &str) -> Result<()> {
        fs::write(self.path(name), text)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store() -> Store {
        let dir = std::env::temp_dir().join(format!(
            "capmin_store_test_{}",
            std::process::id()
        ));
        Store::new(dir.to_str().unwrap()).unwrap()
    }

    #[test]
    fn tensor_roundtrip() {
        let s = tmp_store();
        let ts = vec![
            NamedTensor {
                name: "wb0".into(),
                shape: vec![2, 3],
                data: vec![1., -1., 1., -1., 1., -1.],
            },
            NamedTensor {
                name: "bias".into(),
                shape: vec![],
                data: vec![0.5],
            },
        ];
        s.save_tensors("t.capt", &ts).unwrap();
        assert_eq!(s.load_tensors("t.capt").unwrap(), ts);
    }

    #[test]
    fn fmac_roundtrip() {
        let s = tmp_store();
        let mut a = Fmac::new();
        a.counts[16] = 12345;
        let mut b = Fmac::new();
        b.counts[10] = 7;
        let mut sum = a.clone();
        sum.merge(&b);
        s.save_fmac("f.capt", &[a.clone(), b.clone()], &sum).unwrap();
        let (per, s2) = s.load_fmac("f.capt").unwrap();
        assert_eq!(per, vec![a, b]);
        assert_eq!(s2, sum);
    }

    #[test]
    fn corrupt_file_rejected() {
        let s = tmp_store();
        std::fs::write(s.path("bad.capt"), b"nope").unwrap();
        assert!(s.load_tensors("bad.capt").is_err());
    }
}
