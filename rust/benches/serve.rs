//! Loadgen bench for `capmin serve` (DESIGN.md §12): real TCP clients
//! hammering an in-process server with single-sample `Infer` requests
//! on the cifar_syn smoke model, once with micro-batching disabled
//! (`max_batch = 1`) and once enabled (`max_batch = 8`), plus a
//! warm-cache `Point` record. Reports throughput and p50/p99 latency
//! per configuration and writes `BENCH_serve.json` (uniform
//! bench_harness schema; `speedup_vs_baseline` on the batched row is
//! the throughput ratio over the unbatched server — the acceptance
//! gate's number).

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use bench_harness::Emitter;
use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::serve::{server, Client, ServeOptions};

const DS: &str = "cifar_syn";
const K: usize = 14;
const SIGMA: f64 = 0.02;
const CLIENTS: usize = 8;

fn serve_cfg(tag: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    // identical resources for both configurations (and enough
    // connection workers for every storm client): only --max-batch
    // differs between the b1 and b8 runs
    cfg.threads = CLIENTS;
    cfg.mc_samples = 200;
    cfg.hist_limit = if bench_harness::fast_mode() { 16 } else { 64 };
    cfg.run_dir = std::env::temp_dir()
        .join(format!(
            "capmin_serve_bench_{tag}_{}",
            std::process::id()
        ))
        .to_str()
        .unwrap()
        .into();
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    cfg
}

fn samples(seed: u64, n: usize) -> Vec<Vec<f32>> {
    let px = Dataset::CifarSyn.spec().pixels();
    let mut rng = capmin::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| (0..px).map(|_| rng.pm1(0.5)).collect())
        .collect()
}

struct LoadResult {
    /// Requests per second over the whole storm.
    throughput: f64,
    p50: Duration,
    p99: Duration,
    requests: usize,
}

/// `CLIENTS` concurrent connections, `per_client` single-sample
/// infers each, against a fresh server with the given batch policy.
fn storm(max_batch: usize, per_client: usize) -> LoadResult {
    let tag = format!("b{max_batch}");
    let cfg = serve_cfg(&tag);
    let run_dir = cfg.run_dir.clone();
    let mut opts =
        ServeOptions::new("127.0.0.1:0".parse::<SocketAddr>().unwrap());
    opts.max_batch = max_batch;
    opts.max_wait_ms = 2;
    let srv = server::spawn(cfg, opts).unwrap();
    let addr = srv.addr();

    // pay the one-time warmup (fmac + solve + pack) outside the
    // measured window, then release the connection so every worker
    // slot belongs to the storm
    let mut warm = Client::connect(addr).unwrap();
    warm.infer_logits(DS, K, SIGMA, 0, 1, &samples(1, 1))
        .unwrap();
    drop(warm);

    let t0 = Instant::now();
    let mut latencies: Vec<Duration> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|ci| {
                s.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let xs = samples(100 + ci as u64, 1);
                    let mut lats = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let q0 = Instant::now();
                        c.infer_logits(DS, K, SIGMA, 0, 1, &xs)
                            .unwrap();
                        lats.push(q0.elapsed());
                    }
                    lats
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect()
    });
    let wall = t0.elapsed();
    let mut fin = Client::connect(addr).unwrap();
    fin.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);

    latencies.sort();
    let n = latencies.len();
    LoadResult {
        throughput: n as f64 / wall.as_secs_f64(),
        p50: latencies[n / 2],
        p99: latencies[((n as f64 * 0.99) as usize).min(n - 1)],
        requests: n,
    }
}

fn report(name: &str, r: &LoadResult) {
    println!(
        "{name:<26} {:>8.1} req/s  p50 {:>8.2} ms  p99 {:>8.2} ms  \
         ({} requests, {CLIENTS} clients)",
        r.throughput,
        r.p50.as_secs_f64() * 1e3,
        r.p99.as_secs_f64() * 1e3,
        r.requests
    );
}

fn main() {
    let per_client = bench_harness::scaled(24);
    let mut emitter = Emitter::new("serve");
    bench_harness::header("capmin serve loadgen (cifar_syn, native)");

    let b1 = storm(1, per_client);
    report("infer max-batch=1", &b1);
    emitter.push(
        "serve_infer_b1_p50_latency",
        b1.requests,
        b1.p50.as_nanos() as f64,
        None,
    );
    emitter.push("serve_infer_b1_throughput_rps", b1.requests,
        // record throughput as its period so the schema stays
        // time-shaped: median_ns = ns per request at the observed rate
        1e9 / b1.throughput, None);

    let b8 = storm(8, per_client);
    report("infer max-batch=8", &b8);
    emitter.push(
        "serve_infer_b8_p50_latency",
        b8.requests,
        b8.p50.as_nanos() as f64,
        None,
    );
    emitter.push(
        "serve_infer_b8_throughput_rps",
        b8.requests,
        1e9 / b8.throughput,
        // the acceptance number: batched throughput over unbatched
        Some(b8.throughput / b1.throughput),
    );
    println!(
        "batched throughput = {:.2}x the max-batch=1 configuration",
        b8.throughput / b1.throughput
    );

    // warm Point queries: the memoized solve path end-to-end over TCP
    {
        let cfg = serve_cfg("point");
        let run_dir = cfg.run_dir.clone();
        let opts = ServeOptions::new(
            "127.0.0.1:0".parse::<SocketAddr>().unwrap(),
        );
        let srv = server::spawn(cfg, opts).unwrap();
        let mut c = Client::connect(srv.addr()).unwrap();
        c.point(DS, K, SIGMA, 0, false).unwrap(); // solve once
        let iters = bench_harness::scaled(200);
        let r = bench_harness::bench("point (warm cache)", 3, iters, || {
            c.point(DS, K, SIGMA, 0, false).unwrap();
        });
        bench_harness::report(&r, 1.0, "req");
        emitter.add(&r, None);
        c.shutdown().unwrap();
        srv.join().unwrap();
        let _ = std::fs::remove_dir_all(&run_dir);
    }

    emitter.write();
}
