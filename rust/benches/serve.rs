//! Loadgen bench for `capmin serve` (DESIGN.md §12/§16): real TCP
//! clients hammering an in-process server with single-sample `Infer`
//! requests on the cifar_syn smoke model.
//!
//! Two generators share the file:
//!
//! * the original closed-loop storm (8 blocking clients, back to
//!   back requests) measuring micro-batching: `max_batch = 1` vs
//!   `max_batch = 8`, plus a warm-cache `Point` record;
//! * an open-loop generator — its own epoll/kqueue loop multiplexing
//!   256 (BENCH_FAST) or 1024 non-blocking connections — that sends
//!   requests on a fixed arrival schedule and measures reply latency
//!   from the SCHEDULED arrival, not the actual write, so client-side
//!   queueing cannot hide server latency (no coordinated omission).
//!   One pass runs at 0.6x the calibrated capacity (sustained
//!   p50/p99/p999), one at 3x capacity against a deliberately starved
//!   server (saturated p99 + shed rate in ppm: admission control must
//!   keep latency bounded by refusing, not queueing).
//!
//! Writes `BENCH_serve.json` (uniform bench_harness schema;
//! `speedup_vs_baseline` on the batched row is the throughput ratio
//! over the unbatched server; the `serve_overload_shed_ppm` row keeps
//! the shed rate in its `median_ns` column — the CI gate asserts it
//! is non-zero and that `serve_open_overload_p99_latency` stays
//! within 2x of the recorded baseline).

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use bench_harness::Emitter;
use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::serve::{server, Client, ServeOptions};
use capmin::util::evloop::{
    fd_of, raise_nofile_limit, would_block, Event, Interest, Poller,
};
use capmin::util::json::{obj, Json};

const DS: &str = "cifar_syn";
const K: usize = 14;
const SIGMA: f64 = 0.02;
const CLIENTS: usize = 8;

fn serve_cfg(tag: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    // identical resources for both configurations (and enough
    // connection workers for every storm client): only --max-batch
    // differs between the b1 and b8 runs
    cfg.threads = CLIENTS;
    cfg.mc_samples = 200;
    cfg.hist_limit = if bench_harness::fast_mode() { 16 } else { 64 };
    cfg.run_dir = std::env::temp_dir()
        .join(format!(
            "capmin_serve_bench_{tag}_{}",
            std::process::id()
        ))
        .to_str()
        .unwrap()
        .into();
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    cfg
}

fn samples(seed: u64, n: usize) -> Vec<Vec<f32>> {
    let px = Dataset::CifarSyn.spec().pixels();
    let mut rng = capmin::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| (0..px).map(|_| rng.pm1(0.5)).collect())
        .collect()
}

struct LoadResult {
    /// Requests per second over the whole storm.
    throughput: f64,
    p50: Duration,
    p99: Duration,
    requests: usize,
}

/// `CLIENTS` concurrent connections, `per_client` single-sample
/// infers each, against a fresh server with the given batch policy.
fn storm(max_batch: usize, per_client: usize) -> LoadResult {
    let tag = format!("b{max_batch}");
    let cfg = serve_cfg(&tag);
    let run_dir = cfg.run_dir.clone();
    let mut opts =
        ServeOptions::new("127.0.0.1:0".parse::<SocketAddr>().unwrap());
    opts.max_batch = max_batch;
    opts.max_wait_ms = 2;
    let srv = server::spawn(cfg, opts).unwrap();
    let addr = srv.addr();

    // pay the one-time warmup (fmac + solve + pack) outside the
    // measured window, then release the connection so every worker
    // slot belongs to the storm
    let mut warm = Client::connect(addr).unwrap();
    warm.infer_logits(DS, K, SIGMA, 0, 1, &samples(1, 1))
        .unwrap();
    drop(warm);

    let t0 = Instant::now();
    let mut latencies: Vec<Duration> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|ci| {
                s.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let xs = samples(100 + ci as u64, 1);
                    let mut lats = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let q0 = Instant::now();
                        c.infer_logits(DS, K, SIGMA, 0, 1, &xs)
                            .unwrap();
                        lats.push(q0.elapsed());
                    }
                    lats
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect()
    });
    let wall = t0.elapsed();
    let mut fin = Client::connect(addr).unwrap();
    fin.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);

    latencies.sort();
    let n = latencies.len();
    LoadResult {
        throughput: n as f64 / wall.as_secs_f64(),
        p50: latencies[n / 2],
        p99: latencies[((n as f64 * 0.99) as usize).min(n - 1)],
        requests: n,
    }
}

/// One open-loop connection owned by the loadgen's poll loop.
struct OpenConn {
    sock: TcpStream,
    /// The pre-framed request line this connection replays.
    line: Vec<u8>,
    /// Bytes queued for the socket (appended at each arrival).
    out: Vec<u8>,
    /// `true` while `out` is non-empty and registered for WRITE.
    want_write: bool,
    /// Scheduled arrival stamps of requests not yet answered; the
    /// server replies in order per connection, so the front stamp
    /// always belongs to the next reply line.
    scheduled: VecDeque<Instant>,
    rbuf: Vec<u8>,
    closed: bool,
}

struct OpenResult {
    /// Latency (reply seen - scheduled arrival) of every `ok` reply.
    lat: Vec<Duration>,
    shed: usize,
    sent: usize,
    /// Requests whose connection died before a reply (should be 0).
    lost: usize,
}

impl OpenResult {
    fn quantile(&mut self, q: f64) -> Duration {
        if self.lat.is_empty() {
            return Duration::ZERO;
        }
        self.lat.sort();
        let n = self.lat.len();
        self.lat[((n as f64 * q) as usize).min(n.saturating_sub(1))]
    }
}

/// The framed single-sample `Infer` line connection `ci` replays.
fn framed_infer(ci: usize, xs: &[Vec<f32>]) -> Vec<u8> {
    let row = Json::Arr(
        xs[0].iter().map(|&v| Json::Num(v as f64)).collect(),
    );
    let req = obj(vec![
        ("v", Json::Num(1.0)),
        ("id", Json::Num(ci as f64)),
        ("type", Json::Str("infer".into())),
        ("dataset", Json::Str(DS.into())),
        ("k", Json::Num(K as f64)),
        ("sigma", Json::Num(SIGMA)),
        ("phi", Json::Num(0.0)),
        ("seed", Json::Num(7.0)),
        ("x", Json::Arr(vec![row])),
    ]);
    let mut line = req.to_string().into_bytes();
    line.push(b'\n');
    line
}

/// Drive `total` single-sample infers at a fixed `rps` arrival rate
/// over `n_conns` concurrent non-blocking connections (round-robin
/// assignment), all multiplexed on one client-side poller. Requests
/// fire on schedule whether or not earlier replies have landed —
/// latency is measured from the scheduled arrival.
fn open_loop(
    addr: SocketAddr,
    n_conns: usize,
    rps: f64,
    total: usize,
) -> OpenResult {
    let poller = Poller::new().unwrap();
    let xs = samples(1, 1);
    let mut conns: Vec<OpenConn> = (0..n_conns)
        .map(|ci| {
            let sock = TcpStream::connect(addr).unwrap();
            let _ = sock.set_nodelay(true);
            sock.set_nonblocking(true).unwrap();
            poller
                .register(fd_of(&sock), ci as u64, Interest::READ)
                .unwrap();
            OpenConn {
                sock,
                line: framed_infer(ci, &xs),
                out: Vec::new(),
                want_write: false,
                scheduled: VecDeque::new(),
                rbuf: Vec::new(),
                closed: false,
            }
        })
        .collect();

    let gap = Duration::from_secs_f64(1.0 / rps);
    let mut res = OpenResult {
        lat: Vec::with_capacity(total),
        shed: 0,
        sent: 0,
        lost: 0,
    };
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs(180);
    let mut next_arrival = t0;
    let mut rr = 0usize;
    let mut events: Vec<Event> = Vec::new();

    while res.lat.len() + res.shed + res.lost < total {
        let now = Instant::now();
        if now > deadline {
            eprintln!(
                "open_loop: deadline hit with {} of {total} answered",
                res.lat.len() + res.shed
            );
            break;
        }
        // fire every arrival that is due, on schedule
        while res.sent < total && now >= next_arrival {
            let ci = rr % n_conns;
            rr += 1;
            let c = &mut conns[ci];
            if c.closed {
                res.lost += 1;
            } else {
                c.scheduled.push_back(next_arrival);
                let line = &c.line;
                c.out.extend_from_slice(line);
                flush_conn(&poller, c, ci);
            }
            res.sent += 1;
            next_arrival += gap;
        }
        let timeout = if res.sent < total {
            next_arrival.saturating_duration_since(Instant::now())
        } else {
            Duration::from_millis(10)
        };
        poller.wait(&mut events, Some(timeout)).unwrap();
        for ev in events.drain(..) {
            let ci = ev.token as usize;
            let c = &mut conns[ci];
            if c.closed {
                continue;
            }
            if ev.writable {
                flush_conn(&poller, c, ci);
            }
            if ev.readable || ev.hangup {
                read_conn(&poller, c, &mut res);
            }
        }
    }
    res
}

/// Write `c.out` until empty or the socket pushes back, keeping the
/// poller's WRITE interest in sync.
fn flush_conn(poller: &Poller, c: &mut OpenConn, ci: usize) {
    while !c.out.is_empty() {
        match c.sock.write(&c.out) {
            Ok(0) => break,
            Ok(n) => {
                c.out.drain(..n);
            }
            Err(e) if would_block(&e) => break,
            Err(_) => {
                c.out.clear();
                break;
            }
        }
    }
    let want = !c.out.is_empty();
    if want != c.want_write {
        c.want_write = want;
        let interest =
            if want { Interest::BOTH } else { Interest::READ };
        let _ = poller.modify(fd_of(&c.sock), ci as u64, interest);
    }
}

/// Drain readable bytes and account every complete reply line: a shed
/// bumps `shed`, anything else records its open-loop latency.
fn read_conn(poller: &Poller, c: &mut OpenConn, res: &mut OpenResult) {
    let mut eof = false;
    let mut buf = [0u8; 16 * 1024];
    loop {
        match c.sock.read(&mut buf) {
            Ok(0) => {
                eof = true;
                break;
            }
            Ok(n) => c.rbuf.extend_from_slice(&buf[..n]),
            Err(e) if would_block(&e) => break,
            Err(_) => {
                eof = true;
                break;
            }
        }
    }
    let now = Instant::now();
    let mut start = 0usize;
    while let Some(pos) =
        c.rbuf[start..].iter().position(|&b| b == b'\n')
    {
        let line = &c.rbuf[start..start + pos];
        start += pos + 1;
        let Some(arrived) = c.scheduled.pop_front() else {
            continue; // a reply we never scheduled — ignore
        };
        // sheds are structural; substring probing keeps the hot loop
        // free of a full JSON parse
        if contains(line, b"\"overloaded\":true") {
            res.shed += 1;
        } else {
            res.lat.push(now.duration_since(arrived));
        }
    }
    c.rbuf.drain(..start);
    if eof {
        c.closed = true;
        res.lost += c.scheduled.len();
        c.scheduled.clear();
        let _ = poller.deregister(fd_of(&c.sock));
    }
}

fn contains(hay: &[u8], needle: &[u8]) -> bool {
    hay.windows(needle.len()).any(|w| w == needle)
}

/// Closed-loop calibration against a live server: two blocking
/// clients, back to back warm infers — the sustained open-loop phase
/// runs at 0.6x this rate, the overload phase at 3x.
fn calibrate(addr: SocketAddr) -> f64 {
    let n = bench_harness::scaled(64);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for seed in 0..2u64 {
            s.spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let xs = samples(seed, 1);
                for _ in 0..n {
                    c.infer_logits(DS, K, SIGMA, 0, 7, &xs).unwrap();
                }
            });
        }
    });
    let rate = (2 * n) as f64 / t0.elapsed().as_secs_f64();
    // keep pathological calibrations (cold caches, loaded CI box)
    // inside a band the bench finishes in
    rate.clamp(50.0, 20_000.0)
}

fn report(name: &str, r: &LoadResult) {
    println!(
        "{name:<26} {:>8.1} req/s  p50 {:>8.2} ms  p99 {:>8.2} ms  \
         ({} requests, {CLIENTS} clients)",
        r.throughput,
        r.p50.as_secs_f64() * 1e3,
        r.p99.as_secs_f64() * 1e3,
        r.requests
    );
}

fn main() {
    let per_client = bench_harness::scaled(24);
    let mut emitter = Emitter::new("serve");
    bench_harness::header("capmin serve loadgen (cifar_syn, native)");

    let b1 = storm(1, per_client);
    report("infer max-batch=1", &b1);
    emitter.push(
        "serve_infer_b1_p50_latency",
        b1.requests,
        b1.p50.as_nanos() as f64,
        None,
    );
    emitter.push("serve_infer_b1_throughput_rps", b1.requests,
        // record throughput as its period so the schema stays
        // time-shaped: median_ns = ns per request at the observed rate
        1e9 / b1.throughput, None);

    let b8 = storm(8, per_client);
    report("infer max-batch=8", &b8);
    emitter.push(
        "serve_infer_b8_p50_latency",
        b8.requests,
        b8.p50.as_nanos() as f64,
        None,
    );
    emitter.push(
        "serve_infer_b8_throughput_rps",
        b8.requests,
        1e9 / b8.throughput,
        // the acceptance number: batched throughput over unbatched
        Some(b8.throughput / b1.throughput),
    );
    println!(
        "batched throughput = {:.2}x the max-batch=1 configuration",
        b8.throughput / b1.throughput
    );

    // warm Point queries: the memoized solve path end-to-end over TCP
    {
        let cfg = serve_cfg("point");
        let run_dir = cfg.run_dir.clone();
        let opts = ServeOptions::new(
            "127.0.0.1:0".parse::<SocketAddr>().unwrap(),
        );
        let srv = server::spawn(cfg, opts).unwrap();
        let mut c = Client::connect(srv.addr()).unwrap();
        c.point(DS, K, SIGMA, 0, false).unwrap(); // solve once
        let iters = bench_harness::scaled(200);
        let r = bench_harness::bench("point (warm cache)", 3, iters, || {
            c.point(DS, K, SIGMA, 0, false).unwrap();
        });
        bench_harness::report(&r, 1.0, "req");
        emitter.add(&r, None);
        c.shutdown().unwrap();
        srv.join().unwrap();
        let _ = std::fs::remove_dir_all(&run_dir);
    }

    // ---- open-loop epoll loadgen (DESIGN.md §16) ----
    let n_conns =
        if bench_harness::fast_mode() { 256 } else { 1024 };
    // client and server share this process: >= 2 fds per connection
    raise_nofile_limit((n_conns as u64 + 64) * 4);

    // sustained: default admission limits at 0.6x calibrated capacity
    // — the p50/p99/p999 a healthy server owes its clients while
    // holding every connection open
    {
        let cfg = serve_cfg("open");
        let run_dir = cfg.run_dir.clone();
        let opts = ServeOptions::new(
            "127.0.0.1:0".parse::<SocketAddr>().unwrap(),
        );
        let srv = server::spawn(cfg, opts).unwrap();
        let addr = srv.addr();
        let mut warm = Client::connect(addr).unwrap();
        warm.infer_logits(DS, K, SIGMA, 0, 7, &samples(1, 1))
            .unwrap();
        let cap = calibrate(addr);
        drop(warm);
        let rate = 0.6 * cap;
        let total = ((rate * 4.0) as usize).clamp(n_conns, 4096);
        let mut r = open_loop(addr, n_conns, rate, total);
        let (p50, p99, p999) = (
            r.quantile(0.50),
            r.quantile(0.99),
            r.quantile(0.999),
        );
        println!(
            "open sustained ({n_conns} conns, {rate:.0}/s of \
             {cap:.0}/s cap): p50 {:.2} ms  p99 {:.2} ms  p999 \
             {:.2} ms  ({} ok, {} shed, {} lost)",
            p50.as_secs_f64() * 1e3,
            p99.as_secs_f64() * 1e3,
            p999.as_secs_f64() * 1e3,
            r.lat.len(),
            r.shed,
            r.lost
        );
        emitter.push(
            "serve_open_conns",
            n_conns,
            n_conns as f64,
            None,
        );
        emitter.push(
            "serve_open_sustained_p50_latency",
            r.lat.len(),
            p50.as_nanos() as f64,
            None,
        );
        emitter.push(
            "serve_open_sustained_p99_latency",
            r.lat.len(),
            p99.as_nanos() as f64,
            None,
        );
        emitter.push(
            "serve_open_sustained_p999_latency",
            r.lat.len(),
            p999.as_nanos() as f64,
            None,
        );
        let mut fin = Client::connect(addr).unwrap();
        // server-side phase medians from the unified registry
        // (DESIGN.md §17): where the admitted requests actually spent
        // their time, next to the client-observed latencies above
        let st = fin.stats().unwrap();
        let reg = st.req("stats").req("registry");
        for phase in ["queue_us", "batch_wait_us", "forward_us"] {
            let h = reg.req(&format!("serve.phase.{phase}"));
            let p50_us = h.req("p50_le").as_f64();
            println!(
                "  server phase {phase:<14} p50 <= {p50_us:>8.0} us  \
                 ({} samples)",
                h.req("count").as_f64()
            );
            emitter.push(
                &format!("serve_open_phase_{phase}_p50"),
                h.req("count").as_f64() as usize,
                p50_us * 1e3, // envelope in ns, uniform schema
                None,
            );
        }
        fin.shutdown().unwrap();
        srv.join().unwrap();
        let _ = std::fs::remove_dir_all(&run_dir);
    }

    // saturated: a deliberately starved server (one-worker crews, no
    // batching, queue_cap 32) at 3x ITS capacity. Admission control
    // must shed the excess — bounding p99 for the admitted requests
    // instead of letting the queue stretch latency without limit.
    {
        let mut cfg = serve_cfg("sat");
        cfg.threads = 1;
        let run_dir = cfg.run_dir.clone();
        let mut opts = ServeOptions::new(
            "127.0.0.1:0".parse::<SocketAddr>().unwrap(),
        );
        opts.max_batch = 1;
        opts.queue_cap = 32;
        let srv = server::spawn(cfg, opts).unwrap();
        let addr = srv.addr();
        let mut warm = Client::connect(addr).unwrap();
        warm.infer_logits(DS, K, SIGMA, 0, 7, &samples(1, 1))
            .unwrap();
        let cap = calibrate(addr);
        drop(warm);
        let rate = 3.0 * cap;
        let total = ((rate * 2.0) as usize).clamp(n_conns, 4096);
        let mut r = open_loop(addr, n_conns, rate, total);
        let answered = (r.lat.len() + r.shed).max(1);
        let shed_ppm = r.shed as f64 * 1e6 / answered as f64;
        let p99 = r.quantile(0.99);
        println!(
            "open saturated ({n_conns} conns, {rate:.0}/s = 3x \
             {cap:.0}/s cap): p99 {:.2} ms  shed {} of {} \
             ({:.1}% = {shed_ppm:.0} ppm, {} lost)",
            p99.as_secs_f64() * 1e3,
            r.shed,
            answered,
            100.0 * r.shed as f64 / answered as f64,
            r.lost
        );
        emitter.push(
            "serve_open_overload_p99_latency",
            r.lat.len(),
            p99.as_nanos() as f64,
            None,
        );
        // dimensionless: the shed rate rides in the median_ns column
        // (uniform schema) — the CI gate asserts it is non-zero
        emitter.push(
            "serve_overload_shed_ppm",
            r.sent,
            shed_ppm,
            None,
        );
        let mut fin = Client::connect(addr).unwrap();
        let st = fin.stats().unwrap();
        let adm =
            st.req("stats").req("serving").req("admission");
        println!(
            "server-side admission: rejected_queue {}  \
             rejected_conn {}",
            adm.req("rejected_queue").as_f64(),
            adm.req("rejected_conn").as_f64()
        );
        fin.shutdown().unwrap();
        srv.join().unwrap();
        let _ = std::fs::remove_dir_all(&run_dir);
    }

    emitter.write();
}
