//! Bench: telemetry span overhead (DESIGN.md §17). The hot-path
//! contract is that a `span!` callsite with tracing disabled (the
//! default) costs one relaxed atomic load — instrumenting a kernel
//! must not perturb it. Measures, on a fig8-shaped exact matmul
//! (O=32, K=288, D=768):
//!
//! * the uninstrumented kernel baseline;
//! * the same kernel under a `span!` guard with tracing DISABLED —
//!   the CI gate holds `speedup_vs_baseline >= 0.98` (<= 2%
//!   overhead);
//! * the same under tracing ENABLED (ring writes on), informational.
//!
//! Fully offline; `BENCH_FAST=1` shrinks iteration counts. Results
//! land in `BENCH_obs.json` (uniform schema, see bench_harness).

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report, scaled, Emitter};
use capmin::bnn::{BitMatrix, SubMacEngine};
use capmin::util::rng::Rng;

/// Kernel calls per timed iteration (each under its own span guard,
/// so the measured overhead is per-callsite, smoothed over repeats).
const REPS: usize = 4;

fn main() {
    let mut rng = Rng::new(7);
    let mut emit = Emitter::new("obs");

    // fig8-shaped engine slice: vgg3 conv2 at a reduced batch
    let (o, k, d) = (32usize, 288usize, 768usize);
    let w: Vec<f32> = (0..o * k).map(|_| rng.pm1(0.5)).collect();
    let x: Vec<f32> = (0..d * k).map(|_| rng.pm1(0.5)).collect();
    let eng = SubMacEngine::new(o, k, &w, k);
    let xb = BitMatrix::pack(d, k, &x, false);
    let macs = (REPS * o * k * d) as f64;

    header("span overhead (fig8-shaped kernel: O=32, K=288, D=768)");
    assert!(
        !capmin::obs::tracing_enabled(),
        "tracing must start disabled"
    );
    let iters = scaled(60);
    let base = bench("kernel uninstrumented", 3, iters, || {
        for _ in 0..REPS {
            std::hint::black_box(eng.matmul_exact(&xb));
        }
    });
    report(&base, macs, "MAC");

    let disabled =
        bench("kernel under span! (tracing off)", 3, iters, || {
            for _ in 0..REPS {
                let _s = capmin::span!("bench.obs.kernel");
                std::hint::black_box(eng.matmul_exact(&xb));
            }
        });
    report(&disabled, macs, "MAC");
    println!(
        "    -> {:.4}x vs uninstrumented (CI gate: >= 0.98)",
        base.p50_s / disabled.p50_s
    );

    capmin::obs::set_tracing(true);
    let enabled =
        bench("kernel under span! (tracing on)", 3, iters, || {
            for _ in 0..REPS {
                let _s = capmin::span!("bench.obs.kernel");
                std::hint::black_box(eng.matmul_exact(&xb));
            }
        });
    capmin::obs::set_tracing(false);
    report(&enabled, macs, "MAC");
    println!(
        "    -> {:.4}x vs uninstrumented (ring writes on)",
        base.p50_s / enabled.p50_s
    );

    emit.add(&base, None);
    emit.add(&disabled, Some(&base));
    emit.add(&enabled, Some(&base));
    emit.write();
}
