//! Bench: the design-space explorer's engine (DESIGN.md §13) — the
//! O(n log n) non-dominated sort vs a naive O(n^2) scan, hypervolume
//! of the surviving front, and `CostVector::price` throughput.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report, scaled, Emitter};
use capmin::analog::capacitor::{CapacitorModel, CapacitorSolver};
use capmin::analog::cost::CostVector;
use capmin::analog::neuron::SpikeTimeSet;
use capmin::analog::params::AnalogParams;
use capmin::util::pareto::{dominates, hypervolume, non_dominated};
use capmin::util::rng::Rng;

/// The textbook O(n^2) front — the baseline the sort-based scan is
/// measured against.
fn naive_front(vals: &[Vec<f64>]) -> Vec<usize> {
    (0..vals.len())
        .filter(|&i| {
            !vals
                .iter()
                .enumerate()
                .any(|(j, v)| j != i && dominates(v, &vals[i]))
        })
        .collect()
}

fn random_points(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| (0..d).map(|_| rng.f64()).collect())
        .collect()
}

fn main() {
    let mut emit = Emitter::new("pareto");
    let mut rng = Rng::new(0xF0_17);

    header("non-dominated sort (2D, 4096 points)");
    let pts2 = random_points(&mut rng, 4096, 2);
    let naive2 = bench("naive O(n^2) front, 2D", 2, scaled(20), || {
        std::hint::black_box(naive_front(&pts2));
    });
    report(&naive2, 4096.0, "point");
    emit.add(&naive2, None);

    let fast2 = bench("sort-scan front, 2D", 2, scaled(20), || {
        std::hint::black_box(non_dominated(&pts2));
    });
    report(&fast2, 4096.0, "point");
    emit.add(&fast2, Some(&naive2));

    header("non-dominated sort (4D, 2048 points)");
    let pts4 = random_points(&mut rng, 2048, 4);
    let naive4 = bench("naive O(n^2) front, 4D", 2, scaled(20), || {
        std::hint::black_box(naive_front(&pts4));
    });
    report(&naive4, 2048.0, "point");
    emit.add(&naive4, None);

    let fast4 = bench("sort-scan front, 4D", 2, scaled(20), || {
        std::hint::black_box(non_dominated(&pts4));
    });
    report(&fast4, 2048.0, "point");
    emit.add(&fast4, Some(&naive4));

    // sanity: both algorithms agree before their timings are compared
    assert_eq!(naive_front(&pts2), non_dominated(&pts2));
    assert_eq!(naive_front(&pts4), non_dominated(&pts4));

    header("hypervolume of the surviving 2D front");
    let front2: Vec<Vec<f64>> = non_dominated(&pts2)
        .into_iter()
        .map(|i| pts2[i].clone())
        .collect();
    let r = bench(
        &format!("2D hypervolume, {} front points", front2.len()),
        2,
        scaled(200),
        || {
            std::hint::black_box(hypervolume(&front2, &[1.0, 1.0]));
        },
    );
    report(&r, front2.len() as f64, "point");
    emit.add(&r, None);

    header("CostVector::price (operating-point pricing)");
    let p = AnalogParams::paper_calibrated();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    let c = solver.size_for_window(10, 23);
    // a realistic point: several matmul windows over the same cap
    let times: Vec<Vec<f64>> = [(10, 23), (12, 17), (10, 23), (11, 20)]
        .iter()
        .map(|&(lo, hi)| {
            SpikeTimeSet::new(&p, c, (lo..=hi).collect()).times
        })
        .collect();
    let r = bench("price 4-window point x1000", 5, scaled(200), || {
        for _ in 0..1000 {
            std::hint::black_box(CostVector::price(&p, c, &times));
        }
    });
    report(&r, 1000.0, "point");
    emit.add(&r, None);

    emit.write();
}
