//! Bench: the native backend's matmul kernels — the naive scalar
//! `SubMacEngine` loops vs the cache-blocked tiles vs the thread-pooled
//! tiles (DESIGN.md §9) — plus a whole-model logits pass. Runs fully
//! offline (no artifacts, no xla feature); the recorded speedups are
//! the perf-trajectory evidence for the native inference path
//! (EXPERIMENTS.md §Perf).

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report, BenchResult};
use capmin::backend::arch::model_meta;
use capmin::backend::native::{init_folded, NativeBackend};
use capmin::backend::{kernels, InferenceBackend};
use capmin::bnn::{BitMatrix, ErrorModel, SubMacEngine};
use capmin::util::pool::ScopedPool;
use capmin::util::rng::Rng;

fn rand_pm(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.pm1(0.5)).collect()
}

fn speedup(base: &BenchResult, fast: &BenchResult, what: &str) {
    println!(
        "    -> {:.2}x speedup over {what}",
        base.mean_s / fast.mean_s
    );
}

fn main() {
    let mut rng = Rng::new(42);
    let pool = ScopedPool::new(0);
    println!("worker threads: {}", pool.threads());

    // vgg3 conv2-like shape: O=32, K=288 (9 groups), D = 14*14*16
    let (o, k, d) = (32usize, 288usize, 3136usize);
    let w = rand_pm(&mut rng, o * k);
    let x = rand_pm(&mut rng, d * k);
    let macs = (o * k * d) as f64;
    let eng = SubMacEngine::new(o, k, &w, k);
    let xb = BitMatrix::pack(d, k, &x, false);

    header("exact matmul (O=32, K=288, D=3136)");
    let naive = bench("scalar loop (naive baseline)", 1, 10, || {
        std::hint::black_box(eng.matmul_exact(&xb));
    });
    report(&naive, macs, "MAC");
    let tiled = bench("tiled (cache-blocked)", 1, 10, || {
        std::hint::black_box(kernels::matmul_exact_tiled(&eng, &xb));
    });
    report(&tiled, macs, "MAC");
    speedup(&naive, &tiled, "naive");
    let threaded = bench("tiled + thread pool", 1, 10, || {
        std::hint::black_box(kernels::matmul_exact(&pool, &eng, &xb));
    });
    report(&threaded, macs, "MAC");
    speedup(&naive, &threaded, "naive");

    header("error-model matmul (same shape, stochastic decode)");
    let em = {
        // band-stochastic model so the decode path is non-trivial
        let mut full = vec![vec![0.0f64; 33]; 33];
        for (m, row) in full.iter_mut().enumerate() {
            for dlt in -1i64..=1 {
                let j = (m as i64 + dlt).clamp(0, 32) as usize;
                row[j] += 1.0 / 3.0;
            }
        }
        ErrorModel::from_full(&full)
    };
    let naive_e = bench("scalar loop (naive baseline)", 1, 5, || {
        std::hint::black_box(eng.matmul_error(&xb, &em, 7, 0));
    });
    report(&naive_e, macs, "MAC");
    let tiled_e = bench("tiled (cache-blocked)", 1, 5, || {
        std::hint::black_box(kernels::matmul_error_tiled(
            &eng, &xb, &em, 7, 0,
        ));
    });
    report(&tiled_e, macs, "MAC");
    speedup(&naive_e, &tiled_e, "naive");
    let threaded_e = bench("tiled + thread pool", 1, 5, || {
        std::hint::black_box(kernels::matmul_error(
            &pool, &eng, &xb, &em, 7, 0,
        ));
    });
    report(&threaded_e, macs, "MAC");
    speedup(&naive_e, &threaded_e, "naive");

    header("F_MAC histogram");
    let naive_h = bench("scalar loop", 1, 10, || {
        std::hint::black_box(eng.histogram(&xb));
    });
    report(&naive_h, macs, "MAC");
    let pooled_h = bench("thread pool", 1, 10, || {
        std::hint::black_box(kernels::histogram(&pool, &eng, &xb));
    });
    report(&pooled_h, macs, "MAC");
    speedup(&naive_h, &pooled_h, "scalar");

    header("whole-model logits (vgg3, eval batch, native backend)");
    let meta = model_meta("vgg3").unwrap();
    let folded = init_folded("vgg3").unwrap();
    let be = NativeBackend::new(0);
    let px: usize = meta.in_shape.iter().product();
    let eb = meta.eval_batch;
    let xs = rand_pm(&mut rng, eb * px);
    let ems: Vec<ErrorModel> =
        (0..meta.n_matmuls()).map(|_| ErrorModel::identity()).collect();
    let r = bench("forward pass (error mode)", 1, 5, || {
        std::hint::black_box(
            be.logits("vgg3", &folded, &xs, eb, &ems, 7).unwrap(),
        );
    });
    report(&r, eb as f64, "sample");
    let be1 = NativeBackend::new(1);
    let r1 = bench("forward pass (1 thread)", 1, 5, || {
        std::hint::black_box(
            be1.logits("vgg3", &folded, &xs, eb, &ems, 7).unwrap(),
        );
    });
    report(&r1, eb as f64, "sample");
    speedup(&r1, &r, "single thread");
}
