//! Bench: the native backend's matmul layers — the naive scalar
//! `SubMacEngine` loops vs the word-popcount kernels (scalar tier vs
//! detected SIMD tier vs thread pool, DESIGN.md §11) — plus a
//! whole-model logits pass. Runs fully offline (no artifacts, no xla
//! feature); results land in `BENCH_native_matmul.json` (kernel-level
//! detail lives in benches/kernels.rs, the trajectory headline).

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report, scaled, BenchResult, Emitter};
use capmin::backend::arch::model_meta;
use capmin::backend::kernels::{self, KernelKind};
use capmin::backend::native::{init_folded, NativeBackend};
use capmin::backend::InferenceBackend;
use capmin::bnn::{BitMatrix, ErrorModel, SubMacEngine};
use capmin::util::pool::ScopedPool;
use capmin::util::rng::Rng;

fn rand_pm(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.pm1(0.5)).collect()
}

fn speedup(base: &BenchResult, fast: &BenchResult, what: &str) {
    println!(
        "    -> {:.2}x speedup over {what}",
        base.p50_s / fast.p50_s
    );
}

fn main() {
    let mut rng = Rng::new(42);
    let mut emit = Emitter::new("native_matmul");
    let pool = ScopedPool::new(0);
    let seq = ScopedPool::sequential();
    let simd = KernelKind::detect();
    println!(
        "worker threads: {} | kernel tier: {}",
        pool.threads(),
        simd.name()
    );

    // vgg3 conv2-like shape: O=32, K=288 (9 groups), D = 14*14*16
    let (o, k, d) = (32usize, 288usize, 3136usize);
    let w = rand_pm(&mut rng, o * k);
    let x = rand_pm(&mut rng, d * k);
    let macs = (o * k * d) as f64;
    let eng = SubMacEngine::new(o, k, &w, k);
    let xb = BitMatrix::pack(d, k, &x, false);

    header("exact matmul (O=32, K=288, D=3136)");
    let naive = bench("scalar loop (naive baseline)", 1, scaled(10), || {
        std::hint::black_box(eng.matmul_exact(&xb));
    });
    report(&naive, macs, "MAC");
    emit.add(&naive, None);
    let word = bench("word-popcount (1 thread)", 1, scaled(10), || {
        std::hint::black_box(kernels::matmul_exact(&seq, &eng, &xb, simd));
    });
    report(&word, macs, "MAC");
    speedup(&naive, &word, "naive");
    emit.add(&word, Some(&naive));
    let threaded = bench("word-popcount + thread pool", 1, scaled(10), || {
        std::hint::black_box(kernels::matmul_exact(
            &pool, &eng, &xb, simd,
        ));
    });
    report(&threaded, macs, "MAC");
    speedup(&naive, &threaded, "naive");
    emit.add(&threaded, Some(&naive));

    header("error-model matmul (same shape, stochastic decode)");
    let em = {
        // band-stochastic model so the decode path is non-trivial
        let mut full = vec![vec![0.0f64; 33]; 33];
        for (m, row) in full.iter_mut().enumerate() {
            for dlt in -1i64..=1 {
                let j = (m as i64 + dlt).clamp(0, 32) as usize;
                row[j] += 1.0 / 3.0;
            }
        }
        ErrorModel::from_full(&full)
    };
    let naive_e =
        bench("error scalar loop (naive baseline)", 1, scaled(5), || {
            std::hint::black_box(eng.matmul_error(&xb, &em, 7, 0));
        });
    report(&naive_e, macs, "MAC");
    emit.add(&naive_e, None);
    let word_e = bench("error word kernel (1 thread)", 1, scaled(5), || {
        std::hint::black_box(kernels::matmul_error(
            &seq, &eng, &xb, &em, 7, 0, simd,
        ));
    });
    report(&word_e, macs, "MAC");
    speedup(&naive_e, &word_e, "naive");
    emit.add(&word_e, Some(&naive_e));
    let threaded_e =
        bench("error word kernel + thread pool", 1, scaled(5), || {
            std::hint::black_box(kernels::matmul_error(
                &pool, &eng, &xb, &em, 7, 0, simd,
            ));
        });
    report(&threaded_e, macs, "MAC");
    speedup(&naive_e, &threaded_e, "naive");
    emit.add(&threaded_e, Some(&naive_e));

    header("F_MAC histogram");
    let naive_h = bench("hist scalar loop", 1, scaled(10), || {
        std::hint::black_box(eng.histogram(&xb));
    });
    report(&naive_h, macs, "MAC");
    emit.add(&naive_h, None);
    let pooled_h =
        bench("hist word kernel + thread pool", 1, scaled(10), || {
            std::hint::black_box(kernels::histogram(
                &pool, &eng, &xb, simd,
            ));
        });
    report(&pooled_h, macs, "MAC");
    speedup(&naive_h, &pooled_h, "scalar");
    emit.add(&pooled_h, Some(&naive_h));

    header("whole-model logits (vgg3, eval batch, native backend)");
    let meta = model_meta("vgg3").unwrap();
    let folded = init_folded("vgg3").unwrap();
    let be = NativeBackend::new(0);
    let px: usize = meta.in_shape.iter().product();
    let eb = meta.eval_batch;
    let xs = rand_pm(&mut rng, eb * px);
    let ems: Vec<ErrorModel> =
        (0..meta.n_matmuls()).map(|_| ErrorModel::identity()).collect();
    let r = bench("forward pass (error mode)", 1, scaled(5), || {
        std::hint::black_box(
            be.logits("vgg3", &folded, &xs, eb, &ems, 7).unwrap(),
        );
    });
    report(&r, eb as f64, "sample");
    emit.add(&r, None);
    let be1 = NativeBackend::new(1);
    let r1 = bench("forward pass (1 thread)", 1, scaled(5), || {
        std::hint::black_box(
            be1.logits("vgg3", &folded, &xs, eb, &ems, 7).unwrap(),
        );
    });
    report(&r1, eb as f64, "sample");
    speedup(&r1, &r, "single thread");
    emit.add(&r1, None);

    emit.write();
}
