//! Shared micro-bench harness (no criterion offline; DESIGN.md §8).
//!
//! Warmup + N timed iterations, reports mean / p50 / p95 and a derived
//! throughput. Wall-clock on a single core; variance on this testbed is
//! low, so the simple estimator is adequate for before/after comparisons
//! (EXPERIMENTS.md §Perf).
//!
//! Every bench binary also records its results through [`Emitter`],
//! which writes one uniform `BENCH_<name>.json` next to the Cargo
//! manifest — records of `(name, iters, median_ns,
//! speedup_vs_baseline, git_sha)` — so the perf trajectory is
//! machine-comparable across PRs and CI uploads the files as
//! artifacts. `BENCH_FAST=1` shrinks iteration counts for CI smoke
//! runs ([`scaled`]).

// included per bench binary via #[path]; not every binary uses every
// helper
#![allow(dead_code)]

use std::time::Instant;

use capmin::util::json::{obj, Json};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        self.p50_s * 1e9
    }
}

pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: samples[samples.len() / 2],
        p95_s: samples[((samples.len() as f64 * 0.95) as usize)
            .min(samples.len() - 1)],
    }
}

pub fn report(r: &BenchResult, unit_per_iter: f64, unit: &str) {
    println!(
        "{:<44} {:>10.3} ms/iter  p50 {:>8.3} ms  p95 {:>8.3} ms  \
         {:>12.2} {unit}/s",
        r.name,
        r.mean_s * 1e3,
        r.p50_s * 1e3,
        r.p95_s * 1e3,
        unit_per_iter / r.mean_s,
    );
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// CI smoke mode: `BENCH_FAST=1` shrinks iteration counts so every
/// bench still runs end-to-end (and still emits its JSON) in seconds.
pub fn fast_mode() -> bool {
    std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Iteration count scaled for the mode: full `iters` normally, a
/// quarter (min 2) under `BENCH_FAST=1`.
pub fn scaled(iters: usize) -> usize {
    if fast_mode() {
        (iters / 4).max(2)
    } else {
        iters
    }
}

/// Short git commit of the working tree ("unknown" outside a checkout
/// — records stay comparable either way).
pub fn git_sha() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Uniform `BENCH_<name>.json` writer: every bench binary funnels its
/// results through one schema so the perf trajectory is diffable.
pub struct Emitter {
    bench: String,
    sha: String,
    records: Vec<Json>,
}

impl Emitter {
    pub fn new(bench: &str) -> Emitter {
        Emitter {
            bench: bench.to_string(),
            sha: git_sha(),
            records: vec![],
        }
    }

    /// Record a timed result; `baseline` (when given) yields
    /// `speedup_vs_baseline = baseline_median / this_median`.
    pub fn add(&mut self, r: &BenchResult, baseline: Option<&BenchResult>) {
        let speedup = baseline.map(|b| b.p50_s / r.p50_s);
        self.push(&r.name, r.iters, r.median_ns(), speedup);
    }

    /// Record a raw measurement (one-shot wall times that don't go
    /// through [`bench`], e.g. whole-suite runs).
    pub fn push(
        &mut self,
        name: &str,
        iters: usize,
        median_ns: f64,
        speedup_vs_baseline: Option<f64>,
    ) {
        self.records.push(obj(vec![
            ("name", Json::Str(name.to_string())),
            ("iters", Json::Num(iters as f64)),
            ("median_ns", Json::Num(median_ns)),
            (
                "speedup_vs_baseline",
                match speedup_vs_baseline {
                    Some(s) => Json::Num(s),
                    None => Json::Null,
                },
            ),
            ("git_sha", Json::Str(self.sha.clone())),
        ]));
    }

    /// Write `BENCH_<bench>.json` into the working directory (the
    /// crate root under `cargo bench`).
    pub fn write(&self) {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let json = obj(vec![
            ("bench", Json::Str(self.bench.clone())),
            ("git_sha", Json::Str(self.sha.clone())),
            ("threads", Json::Num(threads as f64)),
            ("fast_mode", Json::Bool(fast_mode())),
            ("results", Json::Arr(self.records.clone())),
        ]);
        let path = format!("BENCH_{}.json", self.bench);
        std::fs::write(&path, json.to_string())
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path} ({} records)", self.records.len());
    }
}
