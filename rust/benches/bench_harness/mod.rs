//! Shared micro-bench harness (no criterion offline; DESIGN.md §8).
//!
//! Warmup + N timed iterations, reports mean / p50 / p95 and a derived
//! throughput. Wall-clock on a single core; variance on this testbed is
//! low, so the simple estimator is adequate for before/after comparisons
//! (EXPERIMENTS.md §Perf).

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: samples[samples.len() / 2],
        p95_s: samples[((samples.len() as f64 * 0.95) as usize)
            .min(samples.len() - 1)],
    }
}

pub fn report(r: &BenchResult, unit_per_iter: f64, unit: &str) {
    println!(
        "{:<44} {:>10.3} ms/iter  p50 {:>8.3} ms  p95 {:>8.3} ms  \
         {:>12.2} {unit}/s",
        r.name,
        r.mean_s * 1e3,
        r.p50_s * 1e3,
        r.p95_s * 1e3,
        unit_per_iter / r.mean_s,
    );
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}
