//! Bench: the analog substrate — capacitor sizing, spike-time sets, and
//! Monte-Carlo P_map extraction (the paper's SPICE-MC replacement).
//! Regenerates Fig. 9's data as part of the run.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report, scaled, Emitter};
use capmin::analog::capacitor::{
    paper_fit, CapacitorModel, CapacitorSolver,
};
use capmin::analog::montecarlo::MonteCarlo;
use capmin::analog::neuron::SpikeTimeSet;
use capmin::analog::params::AnalogParams;
use capmin::util::rng::Rng;

fn main() {
    let p = AnalogParams::paper_calibrated().with_sigma(0.02);
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    let mut emit = Emitter::new("fig9_capacitor");

    header("capacitor sizing (Fig. 9 substrate)");
    let r = bench("closed-form sizing, full k-sweep (28 pts)", 10,
                  scaled(100), || {
        for k in 5..=32 {
            std::hint::black_box(
                solver.size_for_window(17 - k.min(16) / 2, 16 + k / 2),
            );
        }
    });
    report(&r, 28.0, "sizing");
    emit.add(&r, None);

    let r = bench("binary-search sizing, window [10,23]", 2, scaled(20),
                  || {
        std::hint::black_box(
            solver.solve_binary_search(&(10..=23).collect::<Vec<_>>()),
        );
    });
    report(&r, 1.0, "sizing");
    emit.add(&r, None);

    header("Monte-Carlo P_map (1000 samples/level, paper Sec. IV-C)");
    let c = solver.size_for_window(10, 23);
    let set = SpikeTimeSet::new(&p, c, (10..=23).collect());
    let seq = MonteCarlo::new(p);
    let mut rng = Rng::new(7);
    let pm_seq = bench("14x14 P_map extraction (1 thread)", 2,
                       scaled(20), || {
        std::hint::black_box(seq.pmap(&set, &mut rng));
    });
    report(&pm_seq, 14.0 * 1000.0, "sample");
    emit.add(&pm_seq, None);

    let mc = MonteCarlo::new(p).with_threads(0);
    let pm_par = bench("14x14 P_map extraction (chunked pool)", 2,
                       scaled(20), || {
        std::hint::black_box(mc.pmap(&set, &mut rng));
    });
    report(&pm_par, 14.0 * 1000.0, "sample");
    emit.add(&pm_par, Some(&pm_seq));

    let fm_seq = bench("full 33x33 transition map (1 thread)", 2,
                       scaled(20), || {
        std::hint::black_box(seq.full_map(&set, &mut rng));
    });
    report(&fm_seq, 33.0 * 1000.0, "sample");
    emit.add(&fm_seq, None);

    let fm_par = bench("full 33x33 transition map (chunked pool)", 2,
                       scaled(20), || {
        std::hint::black_box(mc.full_map(&set, &mut rng));
    });
    report(&fm_par, 33.0 * 1000.0, "sample");
    emit.add(&fm_par, Some(&fm_seq));

    // Fig. 9 numbers (physics + paper-fit), so `cargo bench` regenerates
    // the table's substance even without trained models
    header("Fig. 9 capacitor values");
    let c32 = solver.size_for_window(1, 32);
    let c14 = solver.size_for_window(10, 23);
    let c16 = solver.size_for_window(9, 24);
    println!(
        "physics : C(32) = {:.2} pF  C(16) = {:.2} pF  C(14) = {:.2} pF \
         -> reduction {:.2}x, CapMin-V premium {:.2}x",
        c32 * 1e12,
        c16 * 1e12,
        c14 * 1e12,
        c32 / c14,
        c16 / c14
    );
    println!(
        "paperfit: C(32) = {:.2} pF  C(16) = {:.2} pF  C(14) = {:.2} pF \
         -> reduction {:.2}x, CapMin-V premium {:.2}x (paper: 14.08x, 1.28x)",
        paper_fit(32) * 1e12,
        paper_fit(16) * 1e12,
        paper_fit(14) * 1e12,
        paper_fit(32) / paper_fit(14),
        paper_fit(16) / paper_fit(14)
    );

    emit.write();
}
