//! Bench: the Rust bit-packed sub-MAC engine — the host-side baseline the
//! paper's framework replaces — vs a naive dense f32 matmul, plus the
//! error-injection path. Supports the L3 perf story in EXPERIMENTS.md.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report, scaled, Emitter};
use capmin::bnn::{BitMatrix, ErrorModel, SubMacEngine};
use capmin::util::rng::Rng;

fn rand_pm(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.pm1(0.5)).collect()
}

fn main() {
    let mut rng = Rng::new(42);
    let mut emit = Emitter::new("engine");
    // vgg3 conv2-like shape: O=32, K=288->288 (9 groups), D = 14*14*16
    let (o, k, d) = (32usize, 288usize, 3136usize);
    let w = rand_pm(&mut rng, o * k);
    let x = rand_pm(&mut rng, d * k);
    let macs = (o * k * d) as f64;

    header("sub-MAC engine (O=32, K=288, D=3136; 2.9 GMAC/iter)");

    // naive dense baseline
    let dense = bench("dense f32 matmul (naive)", 1, scaled(5), || {
        let mut acc = 0.0f32;
        for oi in 0..o {
            for di in 0..d {
                let mut s = 0.0f32;
                for ki in 0..k {
                    s += w[oi * k + ki] * x[di * k + ki];
                }
                acc += s;
            }
        }
        std::hint::black_box(acc);
    });
    report(&dense, macs, "MAC");
    emit.add(&dense, None);

    let eng = SubMacEngine::new(o, k, &w, k);
    let xb = BitMatrix::pack(d, k, &x, false);
    let r = bench("bit-packed XNOR-popcount (exact)", 1, scaled(10), || {
        std::hint::black_box(eng.matmul_exact(&xb));
    });
    report(&r, macs, "MAC");
    emit.add(&r, Some(&dense));

    let em = ErrorModel::identity();
    let r = bench("bit-packed + error injection", 1, scaled(5), || {
        std::hint::black_box(eng.matmul_error(&xb, &em, 7, 0));
    });
    report(&r, macs, "MAC");
    emit.add(&r, None);

    let r = bench("F_MAC histogram extraction", 1, scaled(10), || {
        std::hint::black_box(eng.histogram(&xb));
    });
    report(&r, macs, "MAC");
    emit.add(&r, None);

    header("CDF decode (33-entry row): linear scan vs binary search");
    let mut us: Vec<(usize, f32)> = (0..1_000_000)
        .map(|_| (rng.below(33) as usize, rng.f32()))
        .collect();
    let lin = bench("decode_linear (before)", 1, scaled(10), || {
        let mut acc = 0.0f32;
        for &(l, u) in &us {
            acc += em.decode_linear(l, u);
        }
        std::hint::black_box(acc);
    });
    report(&lin, us.len() as f64, "decode");
    emit.add(&lin, None);
    let r = bench("decode partition_point (after)", 1, scaled(10), || {
        let mut acc = 0.0f32;
        for &(l, u) in &us {
            acc += em.decode(l, u);
        }
        std::hint::black_box(acc);
    });
    report(&r, us.len() as f64, "decode");
    emit.add(&r, Some(&lin));
    us.clear();

    header("bit packing");
    let r = bench("pack activations (D=3136, K=288)", 1, scaled(20), || {
        std::hint::black_box(BitMatrix::pack(d, k, &x, false));
    });
    report(&r, (d * k) as f64, "elem");
    emit.add(&r, None);

    emit.write();
}
