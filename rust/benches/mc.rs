//! Bench: the Monte-Carlo solve modes (DESIGN.md §15) — per-mode pmap
//! wall time, the draws-at-equal-tolerance ratio the fast engine is
//! built around, and an end-to-end cold operating-point solve
//! fast-vs-paper. The `draw reduction (fast vs paper)` record carries
//! the ratio in `speedup_vs_baseline`; CI gates on it staying >= 3
//! (.github/workflows/ci.yml), so a regression in the stopping rule
//! fails loudly rather than silently burning draws.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report, scaled, Emitter};
use capmin::analog::capacitor::{CapacitorModel, CapacitorSolver};
use capmin::analog::montecarlo::{McMode, McSettings, MonteCarlo};
use capmin::analog::params::AnalogParams;
use capmin::analog::pmap::tv_distance;
use capmin::analog::SpikeTimeSet;
use capmin::capmin::Fmac;
use capmin::session::solver::solve;
use capmin::util::rng::Rng;

/// The fig8 sweep's common shape: a 14-level window at the paper's
/// default sigma.
const SIGMA: f64 = 0.02;
const WINDOW: (usize, usize) = (10, 23);

fn main() {
    let p = AnalogParams::paper_calibrated().with_sigma(SIGMA);
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    let c = solver.size_for_window(WINDOW.0, WINDOW.1);
    let set =
        SpikeTimeSet::new(&p, c, (WINDOW.0..=WINDOW.1).collect());
    let mut emit = Emitter::new("mc");

    header("P_map per mode (14-level window, sigma 0.02)");
    let paper_mc = MonteCarlo::new(p);
    let fast_mc = MonteCarlo::new(p).with_mode(McMode::Fast);
    let analytic_mc = MonteCarlo::new(p).with_mode(McMode::Analytic);
    let r_paper = bench("pmap paper (1000 draws/level)", 2,
                        scaled(40), || {
        std::hint::black_box(paper_mc.pmap(&set, &mut Rng::new(7)));
    });
    report(&r_paper, 1.0, "map");
    emit.add(&r_paper, None);
    let r_fast = bench("pmap fast (adaptive stratified)", 2,
                       scaled(40), || {
        std::hint::black_box(fast_mc.pmap(&set, &mut Rng::new(7)));
    });
    report(&r_fast, 1.0, "map");
    emit.add(&r_fast, Some(&r_paper));
    let r_oracle = bench("pmap analytic (closed form)", 2,
                         scaled(200), || {
        std::hint::black_box(analytic_mc.analytic_pmap(&set));
    });
    report(&r_oracle, 1.0, "map");
    emit.add(&r_oracle, Some(&r_paper));

    header("draws at equal tolerance");
    // equal-accuracy certificate first: both sampled maps must sit
    // within the declared per-row TV tolerance of the exact oracle,
    // otherwise the draw ratio below is comparing different answers
    let oracle = analytic_mc.analytic_pmap(&set);
    let (paper_map, paper_draws) =
        paper_mc.pmap_counted(&set, &mut Rng::new(7));
    let (fast_map, fast_draws) =
        fast_mc.pmap_counted(&set, &mut Rng::new(7));
    for i in 0..set.levels.len() {
        let tv_p = tv_distance(&paper_map.p[i], &oracle.p[i]);
        let tv_f = tv_distance(&fast_map.p[i], &oracle.p[i]);
        assert!(tv_p < 0.04, "paper row {i} off-oracle: TV {tv_p}");
        assert!(tv_f < 0.02, "fast row {i} off-oracle: TV {tv_f}");
    }
    let ratio = paper_draws as f64 / fast_draws as f64;
    println!(
        "paper {paper_draws} draws, fast {fast_draws} draws -> \
         {ratio:.2}x reduction (both within TV tolerance of the \
         analytic oracle)"
    );
    // the CI gate reads this record: speedup_vs_baseline = draw ratio
    emit.push(
        "draw reduction (fast vs paper)",
        1,
        fast_draws as f64,
        Some(ratio),
    );

    header("end-to-end cold operating-point solve (phi = 2)");
    let fmacs = vec![
        Fmac::gaussian(5, 2.0, 1e8),
        Fmac::gaussian(16, 2.0, 1e8),
        Fmac::gaussian(16, 2.0, 1e8),
    ];
    let solve_with = |mode| McSettings {
        mode,
        ..McSettings::paper(1000)
    };
    let r_solve_paper = bench("solve paper mode", 1, scaled(20), || {
        std::hint::black_box(solve(
            p,
            42,
            solve_with(McMode::Paper),
            1,
            &fmacs,
            16,
            SIGMA,
            2,
        ));
    });
    report(&r_solve_paper, 1.0, "solve");
    emit.add(&r_solve_paper, None);
    let r_solve_fast = bench("solve fast mode", 1, scaled(20), || {
        std::hint::black_box(solve(
            p,
            42,
            solve_with(McMode::Fast),
            1,
            &fmacs,
            16,
            SIGMA,
            2,
        ));
    });
    report(&r_solve_fast, 1.0, "solve");
    emit.add(&r_solve_fast, Some(&r_solve_paper));
    let r_solve_oracle =
        bench("solve analytic mode", 1, scaled(20), || {
            std::hint::black_box(solve(
                p,
                42,
                solve_with(McMode::Analytic),
                1,
                &fmacs,
                16,
                SIGMA,
                2,
            ));
        });
    report(&r_solve_oracle, 1.0, "solve");
    emit.add(&r_solve_oracle, Some(&r_solve_paper));

    emit.write();
}
