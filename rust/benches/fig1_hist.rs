//! Bench: F_MAC extraction throughput (Fig. 1 pipeline) — the AOT hist
//! artifact vs the Rust native engine, plus the data generator.
//! Requires `make artifacts` and a build with the `xla` feature (the
//! native-path F_MAC numbers live in benches/native_matmul.rs).

#[cfg(feature = "xla")]
#[path = "bench_harness/mod.rs"]
mod bench_harness;

#[cfg(feature = "xla")]
use bench_harness::{bench, header, report, scaled, Emitter};
#[cfg(feature = "xla")]
use capmin::bnn::{BitMatrix, SubMacEngine};
#[cfg(feature = "xla")]
use capmin::coordinator::histogrammer::Histogrammer;
#[cfg(feature = "xla")]
use capmin::coordinator::trainer::Trainer;
#[cfg(feature = "xla")]
use capmin::data::synth::Dataset;
#[cfg(feature = "xla")]
use capmin::data::{Loader, Split};
#[cfg(feature = "xla")]
use capmin::runtime::{artifacts_dir, lit_u32, Runtime};
#[cfg(feature = "xla")]
use capmin::util::rng::Rng;

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!(
        "fig1_hist benches the AOT hist artifact; rebuild with \
         --features xla (native-path numbers: native_matmul bench)"
    );
}

#[cfg(feature = "xla")]
fn main() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping fig1_hist bench: run `make artifacts`");
        return;
    }
    let rt = Runtime::new().unwrap();
    let model = "vgg3_tiny";
    let mi = rt.manifest.model(model).clone();
    let spec = Dataset::FashionSyn.spec();
    let mut emit = Emitter::new("fig1_hist");

    header("data generator");
    let r = bench("synthesize 28x28 sample", 100, scaled(2000), || {
        std::hint::black_box(spec.sample(Split::Train, 123));
    });
    report(&r, 1.0, "sample");
    emit.add(&r, None);

    // fresh (untrained) weights suffice for throughput numbers
    let init = rt.load(model, "init").unwrap();
    let ps = init.run(&[lit_u32(&[2], &[0, 1]).unwrap()]).unwrap();
    let trained = capmin::coordinator::trainer::Trained {
        model: model.to_string(),
        params_state: ps,
        losses: vec![],
    };
    let folded = Trainer::new(&rt).export(&trained).unwrap();

    header(format!(
        "hist artifact ({} batch {})",
        model, mi.hist_batch
    )
    .as_str());
    let hist = Histogrammer::new(&rt);
    let mut loader = Loader::new(
        spec.clone(),
        Split::Train,
        mi.hist_batch,
        512,
        1,
    );
    let hb = mi.hist_batch;
    let aot = bench("F_MAC extraction per batch (AOT path)", 1,
                    scaled(10), || {
        std::hint::black_box(
            hist.extract(model, &folded, &mut loader, hb).unwrap(),
        );
    });
    report(&aot, hb as f64, "sample");
    emit.add(&aot, None);

    header("rust native engine histogram (same sub-MAC count)");
    // conv1-equivalent workload: O=8, K=32, D = 28*28*hb
    let mut rng = Rng::new(5);
    let (o, k) = (8usize, 32usize);
    let d = 28 * 28 * hb;
    let w: Vec<f32> = (0..o * k).map(|_| rng.pm1(0.5)).collect();
    let x: Vec<f32> = (0..d * k).map(|_| rng.pm1(0.5)).collect();
    let eng = SubMacEngine::new(o, k, &w, 9);
    let xb = BitMatrix::pack(d, k, &x, false);
    let r = bench("conv1-shaped histogram (native)", 1, scaled(10), || {
        std::hint::black_box(eng.histogram(&xb));
    });
    report(&r, hb as f64, "sample");
    emit.add(&r, Some(&aot));

    emit.write();
}
