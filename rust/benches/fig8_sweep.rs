//! Bench: the Fig. 8 sweep machinery — error-model construction (CapMin,
//! CapMin-V) and eval-artifact batch latency for both engines (jnp vs
//! Pallas interpret). The jnp/Pallas latency gap is the L1 interpret-mode
//! overhead documented in EXPERIMENTS.md §Perf. Requires `make artifacts`.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report};
use capmin::bnn::ErrorModel;
use capmin::coordinator::config::ExperimentConfig;
use capmin::coordinator::evaluator::{stack_error_models, Evaluator};
use capmin::coordinator::pipeline::Pipeline;
use capmin::coordinator::trainer::Trainer;
use capmin::data::synth::Dataset;
use capmin::runtime::{
    artifacts_dir, lit_f32, lit_u32, lit_u32_scalar, Runtime,
};
use capmin::util::rng::Rng;

fn main() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping fig8_sweep bench: run `make artifacts`");
        return;
    }
    let rt = Runtime::new().unwrap();
    let mut cfg = ExperimentConfig::default();
    cfg.mc_samples = 1000;
    cfg.run_dir = std::env::temp_dir()
        .join("capmin_bench_runs")
        .to_str()
        .unwrap()
        .into();
    let pipe = Pipeline::new(&rt, cfg).unwrap();

    // synthetic per-matmul F_MACs shaped like a trained vgg3_tiny
    let mi = rt.manifest.model("vgg3_tiny").clone();
    let mut fmacs = vec![];
    for m in 0..mi.n_matmuls {
        let mut f = capmin::capmin::Fmac::new();
        let peak = if m == 0 { 5 } else { 16 };
        for lvl in 0..33 {
            let dd = lvl as f64 - peak as f64;
            f.counts[lvl] = (1e8 * (-dd * dd / 8.0).exp()) as u64;
        }
        fmacs.push(f);
    }

    header("error-model construction (per k point of Fig. 8)");
    let r = bench("CapMin hw_config (clean)", 2, 50, || {
        std::hint::black_box(pipe.hw_config(&fmacs, 14, 0.0, 0));
    });
    report(&r, 1.0, "config");
    let r = bench("CapMin hw_config (variation MC)", 2, 20, || {
        std::hint::black_box(pipe.hw_config(&fmacs, 14, 0.02, 0));
    });
    report(&r, 1.0, "config");
    let r = bench("CapMin-V hw_config (phi=2)", 2, 20, || {
        std::hint::black_box(pipe.hw_config(&fmacs, 16, 0.02, 2));
    });
    report(&r, 1.0, "config");

    // eval artifact latency, jnp vs pallas engine
    let init = rt.load("vgg3_tiny", "init").unwrap();
    let ps = init.run(&[lit_u32(&[2], &[0, 1]).unwrap()]).unwrap();
    let trained = capmin::coordinator::trainer::Trained {
        model: "vgg3_tiny".into(),
        params_state: ps,
        losses: vec![],
    };
    let folded = Trainer::new(&rt).export(&trained).unwrap();
    let spec = Dataset::FashionSyn.spec();
    let ems: Vec<ErrorModel> =
        (0..mi.n_matmuls).map(|_| ErrorModel::identity()).collect();
    let _ = stack_error_models(&ems);
    let eb = mi.eval_batch;

    for engine in ["eval", "evalp"] {
        // compile outside the timed region
        rt.load("vgg3_tiny", engine).unwrap();
        let ev = Evaluator::new(&rt, engine);
        let label = format!(
            "{} batch (B={eb}) accuracy pass",
            if engine == "eval" { "jnp engine" } else { "Pallas engine" }
        );
        let r = bench(&label, 1, 5, || {
            std::hint::black_box(
                ev.accuracy("vgg3_tiny", &folded, spec.clone(), &ems,
                            eb, 1)
                    .unwrap(),
            );
        });
        report(&r, eb as f64, "sample");
    }

    header("runtime literal marshalling");
    let mut rng = Rng::new(3);
    let px: usize = mi.in_shape.iter().product();
    let x: Vec<f32> = (0..eb * px).map(|_| rng.pm1(0.5)).collect();
    let x_shape = [&[eb], mi.in_shape.as_slice()].concat();
    let r = bench("batch literal creation", 10, 200, || {
        std::hint::black_box(lit_f32(&x_shape, &x).unwrap());
    });
    report(&r, (eb * px) as f64, "elem");
    let _ = lit_u32_scalar(0);
}
