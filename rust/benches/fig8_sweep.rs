//! Bench: the Fig. 8 sweep machinery — operating-point solves (CapMin,
//! CapMin-V) through `session::solver`, and eval-artifact batch latency
//! for both engines (jnp vs Pallas interpret). The jnp/Pallas latency
//! gap is the L1 interpret-mode overhead documented in EXPERIMENTS.md
//! §Perf. The solve section runs without artifacts; the eval section
//! requires `make artifacts`.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report, scaled, Emitter};
use capmin::analog::params::AnalogParams;
#[cfg(feature = "xla")]
use capmin::bnn::ErrorModel;
#[cfg(feature = "xla")]
use capmin::coordinator::evaluator::{stack_error_models, Evaluator};
#[cfg(feature = "xla")]
use capmin::coordinator::trainer::Trainer;
#[cfg(feature = "xla")]
use capmin::data::synth::Dataset;
#[cfg(feature = "xla")]
use capmin::runtime::{
    artifacts_dir, lit_f32, lit_u32, lit_u32_scalar, Runtime,
};
use capmin::analog::McSettings;
use capmin::session::solver::solve;
#[cfg(feature = "xla")]
use capmin::util::rng::Rng;

/// Synthetic per-matmul F_MACs shaped like a trained vgg3_tiny.
fn synthetic_fmacs(n_matmuls: usize) -> Vec<capmin::capmin::Fmac> {
    (0..n_matmuls)
        .map(|m| {
            capmin::capmin::Fmac::gaussian(
                if m == 0 { 5 } else { 16 },
                2.0,
                1e8,
            )
        })
        .collect()
}

fn main() {
    let p = AnalogParams::paper_calibrated();
    let fmacs = synthetic_fmacs(3);
    let (seed, mc) = (42u64, McSettings::paper(1000));
    let mut emit = Emitter::new("fig8_sweep");

    header("operating-point solve (per k point of Fig. 8)");
    let r = bench("CapMin solve (clean)", 2, scaled(50), || {
        std::hint::black_box(solve(p, seed, mc, 1, &fmacs, 14, 0.0, 0));
    });
    report(&r, 1.0, "solve");
    emit.add(&r, None);
    let var1 = bench("CapMin solve (variation MC, 1 thread)", 2,
                     scaled(20), || {
        std::hint::black_box(solve(p, seed, mc, 1, &fmacs, 14, 0.02, 0));
    });
    report(&var1, 1.0, "solve");
    emit.add(&var1, None);
    let varp = bench("CapMin solve (variation MC, chunked pool)", 2,
                     scaled(20), || {
        std::hint::black_box(solve(p, seed, mc, 0, &fmacs, 14, 0.02, 0));
    });
    report(&varp, 1.0, "solve");
    emit.add(&varp, Some(&var1));
    let r = bench("CapMin-V solve (phi=2)", 2, scaled(20), || {
        std::hint::black_box(solve(p, seed, mc, 1, &fmacs, 16, 0.02, 2));
    });
    report(&r, 1.0, "solve");
    emit.add(&r, None);
    let r = bench("CapMin-V solve (phi=2, chunked pool)", 2, scaled(20),
                  || {
        std::hint::black_box(solve(p, seed, mc, 0, &fmacs, 16, 0.02, 2));
    });
    report(&r, 1.0, "solve");
    emit.add(&r, None);

    emit.write();
    eval_section();
}

#[cfg(not(feature = "xla"))]
fn eval_section() {
    eprintln!(
        "skipping fig8_sweep eval benches: built without the xla feature"
    );
}

#[cfg(feature = "xla")]
fn eval_section() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!(
            "skipping fig8_sweep eval benches: run `make artifacts`"
        );
        return;
    }
    let rt = Runtime::new().unwrap();
    let mi = rt.manifest.model("vgg3_tiny").clone();

    // eval artifact latency, jnp vs pallas engine
    let init = rt.load("vgg3_tiny", "init").unwrap();
    let ps = init.run(&[lit_u32(&[2], &[0, 1]).unwrap()]).unwrap();
    let trained = capmin::coordinator::trainer::Trained {
        model: "vgg3_tiny".into(),
        params_state: ps,
        losses: vec![],
    };
    let folded = Trainer::new(&rt).export(&trained).unwrap();
    let spec = Dataset::FashionSyn.spec();
    let ems: Vec<ErrorModel> =
        (0..mi.n_matmuls).map(|_| ErrorModel::identity()).collect();
    let _ = stack_error_models(&ems);
    let eb = mi.eval_batch;

    for engine in ["eval", "evalp"] {
        // compile outside the timed region
        rt.load("vgg3_tiny", engine).unwrap();
        let ev = Evaluator::new(&rt, engine);
        let label = format!(
            "{} batch (B={eb}) accuracy pass",
            if engine == "eval" { "jnp engine" } else { "Pallas engine" }
        );
        let r = bench(&label, 1, 5, || {
            std::hint::black_box(
                ev.accuracy("vgg3_tiny", &folded, spec.clone(), &ems,
                            eb, 1)
                    .unwrap(),
            );
        });
        report(&r, eb as f64, "sample");
    }

    header("runtime literal marshalling");
    let mut rng = Rng::new(3);
    let px: usize = mi.in_shape.iter().product();
    let x: Vec<f32> = (0..eb * px).map(|_| rng.pm1(0.5)).collect();
    let x_shape = [&[eb], mi.in_shape.as_slice()].concat();
    let r = bench("batch literal creation", 10, 200, || {
        std::hint::black_box(lit_f32(&x_shape, &x).unwrap());
    });
    report(&r, (eb * px) as f64, "elem");
    let _ = lit_u32_scalar(0);
}
