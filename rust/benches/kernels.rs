//! Bench: the width-dispatched popcount sub-MAC microkernels
//! (DESIGN.md §11) — the perf-trajectory headline for the native
//! backend. Measures, on the fig8-sized engine (the vgg3 conv2 shape
//! the accuracy sweeps hammer: O=32, K=288, D=3136):
//!
//! * the naive scalar `SubMacEngine::matmul_exact` baseline vs the
//!   u64 word-popcount kernel at the scalar and detected SIMD tiers
//!   (single thread — the acceptance gate is >= 4x for the SIMD
//!   tier) and on the full pool;
//! * the register-blocked packed path (DESIGN.md §14) vs the word
//!   path, single-thread and pooled, under the autotuned tile
//!   (measured here, cached in `runs/autotune.json`) — the PR 7
//!   acceptance gate is >= 2x over the word SIMD path — plus a
//!   packing-overhead series (pack+compute vs derived compute-only);
//! * fused matmul+histogram vs the separate two-pass data flow,
//!   word and blocked;
//! * the error-model matmul across tiers;
//! * F_MAC extraction end-to-end on the no-XLA cifar_syn smoke
//!   (NativeBackend, untrained vgg7): the pre-rework configuration
//!   (scalar tier, separate histogram) vs the shipped one (SIMD tier,
//!   fused) — the >= 2x end-to-end gate.
//!
//! Fully offline; `BENCH_FAST=1` shrinks iteration counts. Results
//! land in `BENCH_kernels.json` (uniform schema, see bench_harness).

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, header, report, scaled, Emitter};
use capmin::backend::autotune;
use capmin::backend::kernels::{
    self, KernelKind, ResolvedTile, Tile, TileSpec,
};
use capmin::backend::native::{init_folded, NativeBackend};
use capmin::backend::InferenceBackend;
use capmin::bnn::{BitMatrix, ErrorModel, SubMacEngine};
use capmin::data::synth::Dataset;
use capmin::util::pool::ScopedPool;
use capmin::util::rng::Rng;

fn rand_pm(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.pm1(0.5)).collect()
}

fn speedup_line(base: &bench_harness::BenchResult,
                fast: &bench_harness::BenchResult, what: &str) {
    println!(
        "    -> {:.2}x speedup over {what}",
        base.p50_s / fast.p50_s
    );
}

fn main() {
    let mut rng = Rng::new(42);
    let mut emit = Emitter::new("kernels");
    let simd = KernelKind::detect();
    let pool = ScopedPool::new(0);
    let seq = ScopedPool::sequential();
    println!(
        "detected kernel tier: {} | {} worker threads",
        simd.name(),
        pool.threads()
    );

    // fig8-sized engine: vgg3 conv2 — O=32, K=288 (9 groups), D=14*14*16
    let (o, k, d) = (32usize, 288usize, 3136usize);
    let w = rand_pm(&mut rng, o * k);
    let x = rand_pm(&mut rng, d * k);
    let macs = (o * k * d) as f64;
    let eng = SubMacEngine::new(o, k, &w, k);
    let xb = BitMatrix::pack(d, k, &x, false);

    header("exact matmul (fig8-sized engine: O=32, K=288, D=3136)");
    let naive = bench(
        "exact scalar-engine baseline",
        1,
        scaled(10),
        || {
            std::hint::black_box(eng.matmul_exact(&xb));
        },
    );
    report(&naive, macs, "MAC");
    emit.add(&naive, None);

    let word_scalar = bench(
        "exact word-popcount scalar (1 thread)",
        1,
        scaled(10),
        || {
            std::hint::black_box(kernels::matmul_exact(
                &seq,
                &eng,
                &xb,
                KernelKind::Scalar,
            ));
        },
    );
    report(&word_scalar, macs, "MAC");
    speedup_line(&naive, &word_scalar, "scalar engine");
    emit.add(&word_scalar, Some(&naive));

    let word_simd = bench(
        "exact word-popcount simd (1 thread)",
        1,
        scaled(10),
        || {
            std::hint::black_box(kernels::matmul_exact(
                &seq, &eng, &xb, simd,
            ));
        },
    );
    report(&word_simd, macs, "MAC");
    speedup_line(&naive, &word_simd, "scalar engine");
    emit.add(&word_simd, Some(&naive));

    let word_pool = bench(
        "exact word-popcount simd (pool)",
        1,
        scaled(10),
        || {
            std::hint::black_box(kernels::matmul_exact(
                &pool, &eng, &xb, simd,
            ));
        },
    );
    report(&word_pool, macs, "MAC");
    speedup_line(&naive, &word_pool, "scalar engine");
    emit.add(&word_pool, Some(&naive));

    header("register-blocked packed matmul (same engine, DESIGN.md §14)");
    let cache = std::path::Path::new("runs/autotune.json");
    let tile = autotune::resolve(TileSpec::Auto, simd, cache);
    println!(
        "autotuned tile: {} (cache {})",
        tile.name(),
        cache.display()
    );
    let t = match tile {
        ResolvedTile::Blocked(t) => t,
        ResolvedTile::ScalarSafe => Tile::default_for(simd),
    };
    let mut scratch = kernels::PackScratch::default();
    let mut blocked_out = vec![0.0f32; o * d];
    let blocked_1t = bench(
        "exact blocked packed simd (1 thread)",
        1,
        scaled(10),
        || {
            kernels::matmul_exact_tiled_into(
                &seq,
                &eng,
                &xb,
                simd,
                tile,
                &mut scratch,
                &mut blocked_out,
            );
            std::hint::black_box(&blocked_out);
        },
    );
    report(&blocked_1t, macs, "MAC");
    speedup_line(&naive, &blocked_1t, "scalar engine");
    speedup_line(&word_simd, &blocked_1t, "word simd");
    // the CI-gated record: speedup_vs_baseline is vs the word SIMD
    // path (the pre-rework fast path), not the naive engine
    emit.add(&blocked_1t, Some(&word_simd));

    // packing overhead: the blocked timings above repack A and B on
    // every call; time the packing alone and derive compute-only
    let pack_only = bench(
        "blocked packing only (1 thread)",
        1,
        scaled(10),
        || {
            kernels::pack_a_block(&eng.w, 0, o, t.mr, &mut scratch.a);
            kernels::pack_b_block(&xb, 0, d, t.nr, &mut scratch.b);
            std::hint::black_box((&scratch.a, &scratch.b));
        },
    );
    report(&pack_only, macs, "MAC");
    println!(
        "    -> packing is {:.1}% of pack+compute (derived \
         compute-only p50 {:.3} ms)",
        100.0 * pack_only.p50_s / blocked_1t.p50_s,
        (blocked_1t.p50_s - pack_only.p50_s) * 1e3
    );
    emit.add(&pack_only, None);
    emit.push(
        "exact blocked compute-only (derived, 1 thread)",
        blocked_1t.iters,
        (blocked_1t.p50_s - pack_only.p50_s).max(0.0) * 1e9,
        None,
    );

    let blocked_pool = bench(
        "exact blocked packed simd (pool)",
        1,
        scaled(10),
        || {
            kernels::matmul_exact_tiled_into(
                &pool,
                &eng,
                &xb,
                simd,
                tile,
                &mut scratch,
                &mut blocked_out,
            );
            std::hint::black_box(&blocked_out);
        },
    );
    report(&blocked_pool, macs, "MAC");
    speedup_line(&naive, &blocked_pool, "scalar engine");
    emit.add(&blocked_pool, Some(&naive));

    // bit-equality cross-check: the speedup only counts if the blocked
    // path answers exactly like the word path and the naive engine
    let want_exact = eng.matmul_exact(&xb);
    assert_eq!(
        kernels::matmul_exact(&seq, &eng, &xb, simd),
        want_exact,
        "word path drifted from the engine"
    );
    assert_eq!(
        kernels::matmul_exact_tiled(&seq, &eng, &xb, simd, tile),
        want_exact,
        "blocked packed path drifted from the engine"
    );

    header("fused F_MAC histogram (same engine)");
    let separate = bench(
        "separate matmul+hist (simd, 1 thread)",
        1,
        scaled(10),
        || {
            std::hint::black_box(kernels::histogram(
                &seq, &eng, &xb, simd,
            ));
            std::hint::black_box(kernels::matmul_exact(
                &seq, &eng, &xb, simd,
            ));
        },
    );
    report(&separate, macs, "MAC");
    emit.add(&separate, None);
    let fused = bench(
        "fused matmul+hist (simd, 1 thread)",
        1,
        scaled(10),
        || {
            std::hint::black_box(kernels::matmul_exact_fused(
                &seq, &eng, &xb, simd,
            ));
        },
    );
    report(&fused, macs, "MAC");
    speedup_line(&separate, &fused, "separate passes");
    emit.add(&fused, Some(&separate));
    let fused_blocked = bench(
        "fused blocked matmul+hist (simd, 1 thread)",
        1,
        scaled(10),
        || {
            std::hint::black_box(kernels::matmul_exact_fused_tiled_into(
                &seq,
                &eng,
                &xb,
                simd,
                tile,
                &mut scratch,
                &mut blocked_out,
            ));
        },
    );
    report(&fused_blocked, macs, "MAC");
    speedup_line(&separate, &fused_blocked, "separate passes");
    emit.add(&fused_blocked, Some(&separate));
    // fused blocked must agree with the fused word path, bit for bit
    let (word_out, word_hist) =
        kernels::matmul_exact_fused(&seq, &eng, &xb, simd);
    let (blk_out, blk_hist) =
        kernels::matmul_exact_fused_tiled(&seq, &eng, &xb, simd, tile);
    assert_eq!(blk_out, word_out, "fused blocked out drift");
    assert_eq!(blk_hist, word_hist, "fused blocked hist drift");

    header("error-model matmul (same engine, stochastic decode)");
    let em = {
        // band-stochastic model so the decode path is non-trivial
        let mut full = vec![vec![0.0f64; 33]; 33];
        for (m, row) in full.iter_mut().enumerate() {
            for dlt in -1i64..=1 {
                let j = (m as i64 + dlt).clamp(0, 32) as usize;
                row[j] += 1.0 / 3.0;
            }
        }
        ErrorModel::from_full(&full)
    };
    let naive_e = bench(
        "error scalar-engine baseline",
        1,
        scaled(5),
        || {
            std::hint::black_box(eng.matmul_error(&xb, &em, 7, 0));
        },
    );
    report(&naive_e, macs, "MAC");
    emit.add(&naive_e, None);
    let err_simd = bench(
        "error word-kernel simd (1 thread)",
        1,
        scaled(5),
        || {
            std::hint::black_box(kernels::matmul_error(
                &seq, &eng, &xb, &em, 7, 0, simd,
            ));
        },
    );
    report(&err_simd, macs, "MAC");
    speedup_line(&naive_e, &err_simd, "scalar engine");
    emit.add(&err_simd, Some(&naive_e));
    let err_pool = bench(
        "error word-kernel simd (pool)",
        1,
        scaled(5),
        || {
            std::hint::black_box(kernels::matmul_error(
                &pool, &eng, &xb, &em, 7, 0, simd,
            ));
        },
    );
    report(&err_pool, macs, "MAC");
    speedup_line(&naive_e, &err_pool, "scalar engine");
    emit.add(&err_pool, Some(&naive_e));

    header("F_MAC end-to-end (no-XLA cifar_syn smoke, untrained vgg7)");
    let spec = Dataset::CifarSyn.spec();
    let folded = init_folded(spec.model).unwrap();
    let limit = if bench_harness::fast_mode() { 8 } else { 16 };
    let before =
        NativeBackend::with_options(0, KernelKind::Scalar, false);
    let fmac_before = bench(
        "fmac end-to-end baseline (scalar, separate)",
        1,
        scaled(3),
        || {
            std::hint::black_box(
                before
                    .fmac(spec.model, &folded, spec.clone(), limit, 9)
                    .unwrap(),
            );
        },
    );
    report(&fmac_before, limit as f64, "sample");
    emit.add(&fmac_before, None);
    let after = NativeBackend::with_options(0, simd, true);
    let fmac_after = bench(
        "fmac end-to-end (simd, fused)",
        1,
        scaled(3),
        || {
            std::hint::black_box(
                after
                    .fmac(spec.model, &folded, spec.clone(), limit, 9)
                    .unwrap(),
            );
        },
    );
    report(&fmac_after, limit as f64, "sample");
    speedup_line(&fmac_before, &fmac_after, "pre-rework fmac");
    emit.add(&fmac_after, Some(&fmac_before));

    // cross-check while we're here: the two configurations must agree
    let a = before
        .fmac(spec.model, &folded, spec.clone(), limit, 9)
        .unwrap();
    let b = after
        .fmac(spec.model, &folded, spec.clone(), limit, 9)
        .unwrap();
    assert_eq!(a.per_matmul, b.per_matmul, "fused/unfused F_MAC drift");
    assert_eq!(a.accuracy, b.accuracy, "fused/unfused accuracy drift");

    // trajectory gates (DESIGN.md §11) — reported, not asserted:
    // fast-mode/loaded-machine medians are too noisy to hard-fail on
    header("trajectory gates");
    let gate = |name: &str, got: f64, want: f64| {
        println!(
            "  {} {name}: {got:.2}x (gate {want}x)",
            if got >= want { "PASS" } else { "MISS" }
        );
    };
    gate(
        "exact simd 1-thread vs scalar engine",
        naive.p50_s / word_simd.p50_s,
        4.0,
    );
    gate(
        "exact blocked 1-thread vs word simd (PR 7)",
        word_simd.p50_s / blocked_1t.p50_s,
        2.0,
    );
    gate(
        "fused vs separate matmul+hist",
        separate.p50_s / fused.p50_s,
        1.0,
    );
    gate(
        "fmac end-to-end (simd fused vs scalar separate)",
        fmac_before.p50_s / fmac_after.p50_s,
        2.0,
    );

    emit.write();
}
