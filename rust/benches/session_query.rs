//! Bench: the `DesignSession` query service — sequential `query` loop
//! vs the thread-parallel `query_many` over a fig8-shaped k-sweep, and
//! warm-cache replay from memory and from `runs/points/`; plus a
//! hardware-only mini-suite through the plan engine. Runs entirely
//! offline (no artifacts needed) and writes a
//! `BENCH_session_query.json` summary (uniform bench_harness schema)
//! next to the Cargo manifest so the perf trajectory is comparable
//! across PRs.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use std::time::Instant;

use bench_harness::Emitter;
use capmin::capmin::Fmac;
use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::plan;
use capmin::plan::planner::{Planner, SuiteOptions};
use capmin::session::{DesignSession, OperatingPointSpec};

// Same fixture as tests/common/mod.rs (bench targets can't share the
// tests/ module tree); the matmul count is arbitrary here because
// every query is hardware-only — no error-model/model alignment.
fn synthetic_fmacs(n_matmuls: usize) -> (Vec<Fmac>, Fmac) {
    let mut per = vec![];
    let mut sum = Fmac::new();
    for m in 0..n_matmuls {
        let f = Fmac::gaussian(if m == 0 { 5 } else { 16 }, 2.0, 1e8);
        sum.merge(&f);
        per.push(f);
    }
    (per, sum)
}

fn fresh_session(tag: &str, persist: bool) -> DesignSession {
    let dir = std::env::temp_dir().join(format!(
        "capmin_session_bench_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ExperimentConfig::default();
    cfg.mc_samples = 1000;
    cfg.point_cache = persist;
    cfg.run_dir = dir.to_str().unwrap().into();
    let session = DesignSession::builder().config(cfg).build().unwrap();
    let (per, sum) = synthetic_fmacs(3);
    session.put_fmac(Dataset::FashionSyn, per, sum);
    session
}

fn cleanup(session: &DesignSession) {
    let _ = std::fs::remove_dir_all(&session.config().run_dir);
}

fn main() {
    // the fig8 k-sweep at sigma > 0: every point pays a Monte-Carlo
    // full map per matmul — the stage query_many parallelizes
    let specs: Vec<OperatingPointSpec> = ExperimentConfig::default()
        .ks
        .iter()
        .map(|&k| {
            OperatingPointSpec::new(Dataset::FashionSyn, k, 0.02, 0)
        })
        .collect();
    println!(
        "fig8-shaped sweep: {} hardware points, {} MC samples/level, \
         {} worker threads available",
        specs.len(),
        1000,
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    );

    // cold sequential
    let seq = fresh_session("seq", false);
    let t0 = Instant::now();
    for s in &specs {
        seq.query(s).unwrap();
    }
    let t_seq = t0.elapsed();
    println!("sequential query loop : {:>8.1} ms", t_seq.as_secs_f64() * 1e3);
    cleanup(&seq);

    // cold parallel
    let par = fresh_session("par", true);
    let t0 = Instant::now();
    let points = par.query_many(&specs).unwrap();
    let t_par = t0.elapsed();
    println!(
        "query_many (parallel) : {:>8.1} ms  ({:.2}x vs sequential)",
        t_par.as_secs_f64() * 1e3,
        t_seq.as_secs_f64() / t_par.as_secs_f64().max(1e-9)
    );
    assert_eq!(points.len(), specs.len());

    // warm replay from the in-memory map
    let t0 = Instant::now();
    par.query_many(&specs).unwrap();
    let t_mem = t0.elapsed();
    println!(
        "replay (memory cache) : {:>8.3} ms",
        t_mem.as_secs_f64() * 1e3
    );

    // warm replay from runs/points/ only (fresh session, same run dir)
    let mut cfg = par.config().clone();
    cfg.point_cache = true;
    let disk = DesignSession::builder().config(cfg).build().unwrap();
    let (per, sum) = synthetic_fmacs(3);
    disk.put_fmac(Dataset::FashionSyn, per, sum);
    let t0 = Instant::now();
    disk.query_many(&specs).unwrap();
    let t_disk = t0.elapsed();
    println!(
        "replay (disk cache)   : {:>8.3} ms",
        t_disk.as_secs_f64() * 1e3
    );
    let s = disk.stats();
    assert_eq!(s.disk_hits, specs.len() as u64, "all served from disk");
    assert_eq!(s.solves, 0, "no MC rerun on replay");
    println!(
        "disk session stats: {} queries | {} disk hits | {} solves",
        s.queries, s.disk_hits, s.solves
    );
    cleanup(&par);

    // hardware-only mini-suite through the plan engine: wall time and
    // dedup stats of the declarative path (table1 + fig5 + fig9 avoid
    // accuracy evaluation, so this runs anywhere)
    let suite = fresh_session("suite", true);
    let mut planner = Planner::new(&suite);
    for name in ["table1", "fig5", "fig9"] {
        planner
            .add(plan::build(name, &[Dataset::FashionSyn]).unwrap());
    }
    let t0 = Instant::now();
    let outcome = planner
        .run_suite(&SuiteOptions {
            suite_id: Some("bench".into()),
            ..Default::default()
        })
        .unwrap();
    let t_suite = t0.elapsed();
    let ss = suite.stats();
    println!(
        "mini-suite ({} plans) : {:>8.1} ms  ({} queries, {} solves)",
        outcome.completed.len(),
        t_suite.as_secs_f64() * 1e3,
        ss.queries,
        ss.solves
    );
    cleanup(&suite);

    // perf-trajectory summary for CI (rust/BENCH_session_query.json):
    // one-shot wall times recorded through the shared harness schema
    let ns = |d: std::time::Duration| d.as_secs_f64() * 1e9;
    let mut emit = Emitter::new("session_query");
    emit.push(
        &format!("sequential query loop ({} specs)", specs.len()),
        1,
        ns(t_seq),
        None,
    );
    emit.push(
        "query_many (parallel)",
        1,
        ns(t_par),
        Some(t_seq.as_secs_f64() / t_par.as_secs_f64().max(1e-9)),
    );
    emit.push(
        "replay (memory cache)",
        1,
        ns(t_mem),
        Some(t_seq.as_secs_f64() / t_mem.as_secs_f64().max(1e-9)),
    );
    emit.push(
        "replay (disk cache)",
        1,
        ns(t_disk),
        Some(t_seq.as_secs_f64() / t_disk.as_secs_f64().max(1e-9)),
    );
    emit.push(
        &format!(
            "mini-suite ({} plans, {} queries, {} solves)",
            outcome.completed.len(),
            ss.queries,
            ss.solves
        ),
        1,
        ns(t_suite),
        None,
    );
    emit.write();
}
