//! Plan-engine integration tests (offline, native backend): the
//! acceptance gates of DESIGN.md §10 —
//!  * `suite` over all experiments issues each unique
//!    `OperatingPointSpec` to the solver at most once per run
//!    (asserted through `SessionStats`), and
//!  * a rerun resumes from `runs/suite/<id>/manifest.json` without
//!    re-solving completed plans.

use std::collections::HashSet;

use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::plan;
use capmin::plan::planner::{Planner, SuiteOptions};
use capmin::session::DesignSession;

mod common;
use common::{artifacts_present, inject_fmacs, tmp_dir};

fn tiny_cfg(dir: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    cfg.mc_samples = 60;
    cfg.eval_limit = 8;
    cfg.hist_limit = 8;
    cfg.n_seeds = 1;
    // 32 anchors headline's choose_k; 14/16 anchor fig9 and CapMin-V
    cfg.ks = vec![32, 16, 14, 10];
    cfg.run_dir = dir.to_string();
    cfg
}

fn fresh_session(cfg: ExperimentConfig) -> DesignSession {
    let session = DesignSession::builder().config(cfg).build().unwrap();
    inject_fmacs(&session, Dataset::FashionSyn);
    session
}

#[test]
fn suite_issues_each_unique_spec_at_most_once() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let dir = tmp_dir("suite_dedup");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = tiny_cfg(&dir);
    let datasets = [Dataset::FashionSyn];

    // expected counts straight from the declared grids
    let plans = plan::all_plans(&datasets);
    let mut declared = 0usize;
    let mut uniq: HashSet<String> = HashSet::new();
    let mut uniq_hw: HashSet<String> = HashSet::new();
    for p in &plans {
        for s in p.specs(&cfg) {
            declared += 1;
            uniq.insert(s.cache_key(&cfg));
            uniq_hw.insert(s.hw_cache_key(&cfg));
        }
    }
    assert!(
        declared > uniq.len(),
        "suite grids must overlap (fig8 and headline share theirs)"
    );

    let session = fresh_session(cfg);
    let mut planner = Planner::new(&session);
    for p in plan::all_plans(&datasets) {
        planner.add(p);
    }
    let outcome = planner.run_suite(&SuiteOptions::default()).unwrap();
    assert_eq!(outcome.completed.len(), plan::PLAN_NAMES.len());
    assert!(outcome.restored.is_empty());

    let s = session.stats();
    assert_eq!(
        s.queries as usize,
        uniq.len(),
        "the planner queries exactly the deduplicated union"
    );
    assert_eq!(
        s.solves as usize,
        uniq_hw.len(),
        "each unique hardware point solves exactly once per run"
    );
    assert_eq!(
        s.deduped, 0,
        "cross-plan dedup happens before the batch reaches the session"
    );

    // manifest + markdown artifacts landed under runs/suite/<id>/
    assert!(outcome.dir.join("manifest.json").exists());
    for name in plan::PLAN_NAMES {
        assert!(
            outcome.dir.join(format!("{name}.md")).exists(),
            "missing {name}.md"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn suite_resumes_from_manifest_without_resolving() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let dir = tmp_dir("suite_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let sid = Some("resume-test".to_string());
    let ds = [Dataset::FashionSyn];

    // run 1: two plans (hardware-only grids) complete and checkpoint
    {
        let session = fresh_session(tiny_cfg(&dir));
        let mut planner = Planner::new(&session);
        planner.add(plan::build("table2", &ds).unwrap());
        planner.add(plan::build("fig9", &ds).unwrap());
        let outcome = planner
            .run_suite(&SuiteOptions {
                suite_id: sid.clone(),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(outcome.completed, vec!["table2", "fig9"]);
        assert!(outcome.restored.is_empty());
        assert!(session.stats().solves > 0);
    }

    // run 2 (a "rerun after kill", plus one new plan): the completed
    // plans are restored from the manifest — their specs never reach
    // the solver — and only the new plan runs
    {
        let session = fresh_session(tiny_cfg(&dir));
        let mut planner = Planner::new(&session);
        planner.add(plan::build("table2", &ds).unwrap());
        planner.add(plan::build("fig9", &ds).unwrap());
        planner.add(plan::build("fig5", &ds).unwrap());
        let outcome = planner
            .run_suite(&SuiteOptions {
                suite_id: sid.clone(),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(outcome.restored, vec!["table2", "fig9"]);
        assert_eq!(outcome.completed, vec!["fig5"]);
        let s = session.stats();
        assert_eq!(
            (s.queries, s.solves),
            (0, 0),
            "restored plans are skipped entirely (fig5 declares an \
             empty grid)"
        );
    }

    // run 2b: same pinned suite id, different dataset selection —
    // fig5 declares an empty grid but is dataset-scoped, so the
    // fashion_syn completion must NOT be restored for cifar_syn
    {
        let session = fresh_session(tiny_cfg(&dir));
        inject_fmacs(&session, Dataset::CifarSyn);
        let mut planner = Planner::new(&session);
        planner
            .add(plan::build("fig5", &[Dataset::CifarSyn]).unwrap());
        let outcome = planner
            .run_suite(&SuiteOptions {
                suite_id: sid.clone(),
                ..Default::default()
            })
            .unwrap();
        assert!(
            outcome.restored.is_empty(),
            "a different --dataset selection must not restore fig5"
        );
        assert_eq!(outcome.completed, vec!["fig5"]);
    }

    // run 3: --no-resume re-runs every plan, but the operating-point
    // cache still answers — resume saves the queries, the cache saves
    // the solves
    {
        let session = fresh_session(tiny_cfg(&dir));
        let mut planner = Planner::new(&session);
        planner.add(plan::build("fig9", &ds).unwrap());
        let outcome = planner
            .run_suite(&SuiteOptions {
                suite_id: sid.clone(),
                resume: false,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(outcome.completed, vec!["fig9"]);
        let s = session.stats();
        assert_eq!(s.queries, 2, "fig9 declares two specs");
        assert_eq!(s.solves, 0, "both replay from runs/points/");
        assert_eq!(s.disk_hits, 2);
    }

    // run 4: a config drift (different MC scale) invalidates the
    // manifest wholesale — nothing is restored
    {
        let mut cfg = tiny_cfg(&dir);
        cfg.mc_samples = 61;
        let session = fresh_session(cfg);
        let mut planner = Planner::new(&session);
        planner.add(plan::build("fig9", &ds).unwrap());
        let outcome = planner
            .run_suite(&SuiteOptions {
                suite_id: sid.clone(),
                ..Default::default()
            })
            .unwrap();
        assert!(outcome.restored.is_empty());
        assert_eq!(outcome.completed, vec!["fig9"]);
        assert_eq!(
            session.stats().solves,
            2,
            "changed config keys miss the point cache and re-solve"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn suite_emits_requested_artifacts() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let dir = tmp_dir("suite_emit");
    let _ = std::fs::remove_dir_all(&dir);
    let session = fresh_session(tiny_cfg(&dir));
    let mut planner = Planner::new(&session);
    planner.add(plan::build("table1", &[Dataset::FashionSyn]).unwrap());
    let outcome = planner
        .run_suite(&SuiteOptions {
            emit: vec![
                capmin::plan::report::Emit::Json,
                capmin::plan::report::Emit::Csv,
            ],
            suite_id: Some("emit-test".into()),
            ..Default::default()
        })
        .unwrap();
    for ext in ["md", "json", "csv"] {
        assert!(
            outcome.dir.join(format!("table1.{ext}")).exists(),
            "missing table1.{ext}"
        );
    }
    // the JSON artifact parses and is typed
    let text = std::fs::read_to_string(outcome.dir.join("table1.json"))
        .unwrap();
    let j = capmin::util::json::Json::parse(&text).unwrap();
    assert_eq!(j.req("plan").as_str(), "table1");
    assert!(!j.req("sections").as_arr().is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pareto_plan_reports_a_sound_frontier() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    use capmin::experiments::pareto::{
        candidates, frontier, ParetoPlan, SENSES,
    };
    use capmin::plan::report::Emit;
    use capmin::plan::ExperimentPlan;
    use capmin::util::pareto::{dominates, minimized};

    let dir = tmp_dir("suite_pareto");
    let _ = std::fs::remove_dir_all(&dir);
    let session = fresh_session(tiny_cfg(&dir));
    let plan = ParetoPlan {
        datasets: vec![Dataset::FashionSyn],
    };
    let points =
        session.query_many(&plan.specs(session.config())).unwrap();

    // the reported frontier is exactly the non-dominated subset
    let mut it = points.iter();
    let cands = candidates(session.config(), &mut it);
    let front = frontier(&cands);
    assert!(!front.is_empty() && front.len() <= cands.len());
    let vals: Vec<Vec<f64>> = cands
        .iter()
        .map(|c| minimized(&c.objectives(), &SENSES))
        .collect();
    for &i in &front {
        assert!(
            !front.iter().any(|&j| dominates(&vals[j], &vals[i])),
            "frontier member {i} is dominated"
        );
    }
    for i in 0..cands.len() {
        if !front.contains(&i) {
            assert!(
                front.iter().any(|&f| dominates(&vals[f], &vals[i])),
                "excluded candidate {i} is not dominated"
            );
        }
    }
    // both families are priced candidates under tiny_cfg's ks
    assert!(cands.iter().any(|c| c.family == "capmin"));
    assert!(cands.iter().any(|c| c.family == "capmin-v"));

    // the reduction renders all three emit formats with the series
    let rep = plan.reduce(&session, &points).unwrap();
    let json = rep.render(Emit::Json);
    assert!(json.contains("pareto_fashion_syn"), "{json}");
    assert!(json.contains("on_front"), "{json}");
    assert!(!rep.render(Emit::Md).is_empty());
    assert!(!rep.render(Emit::Csv).is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}
