//! Telemetry integration tests (DESIGN.md §17): the cross-layer
//! metrics registry stays exact under concurrent writers, and a real
//! loopback serve run under tracing exports a valid Chrome-trace file
//! whose per-request trace ids link the queue → batch → forward →
//! reply spans across threads.
//!
//! Tracing is process-global, so everything that needs it enabled
//! lives in this integration binary — the lib unit tests pin the
//! disabled fast path and must never see it switched on.

use std::collections::HashSet;

use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::obs;
use capmin::serve::{client::Client, server, ServeOptions};
use capmin::util::json::Json;

mod common;
use common::{artifacts_present, tmp_dir};

#[test]
fn registry_counts_are_exact_under_concurrent_increments() {
    let reg = obs::registry::Registry::new();
    let h = reg.hist("t.lat_us", 16);
    std::thread::scope(|s| {
        for t in 0..8u64 {
            let reg = &reg;
            let h = h.clone();
            s.spawn(move || {
                // one cached handle, one per-call resolution — both
                // must land every increment
                let c = reg.counter("t.hits");
                for i in 0..10_000u64 {
                    c.inc();
                    reg.counter("t.by_name").add(2);
                    h.record(i % 7 + t);
                }
            });
        }
    });
    assert_eq!(reg.counter("t.hits").get(), 80_000);
    assert_eq!(reg.counter("t.by_name").get(), 160_000);
    assert_eq!(h.count(), 80_000);
    let j = reg.snapshot_json();
    assert_eq!(j.req("t.hits").as_f64(), 80_000.0);
    assert_eq!(j.req("t.lat_us").req("count").as_f64(), 80_000.0);
    // the prom exposition agrees with the snapshot
    let prom = reg.prom_text();
    assert!(prom.contains("capmin_t_hits 80000"), "{prom}");
    assert!(prom.contains("capmin_t_lat_us_count 80000"), "{prom}");
}

#[test]
fn loopback_serve_trace_links_request_spans_across_threads() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    obs::set_tracing(true);

    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    cfg.threads = 2;
    cfg.mc_samples = 100;
    cfg.hist_limit = 32;
    cfg.eval_limit = 16;
    cfg.run_dir = tmp_dir("obs_trace");
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    let run_dir = cfg.run_dir.clone();
    let mut opts =
        ServeOptions::new("127.0.0.1:0".parse().unwrap());
    opts.max_batch = 4;
    opts.max_wait_ms = 5;
    let srv = server::spawn(cfg, opts).unwrap();
    let addr = srv.addr();

    let mut c = Client::connect(addr).unwrap();
    let px = Dataset::FashionSyn.spec().pixels();
    let mut rng = capmin::util::rng::Rng::new(5);
    let xs: Vec<Vec<f32>> = (0..2)
        .map(|_| (0..px).map(|_| rng.pm1(0.5)).collect())
        .collect();

    // every admitted compute request echoes its own trace id
    let p = c.point("fashion_syn", 14, 0.02, 0, false).unwrap();
    let point_trace =
        u64::from_str_radix(p.req("trace").as_str(), 16).unwrap();
    assert_ne!(point_trace, 0, "point reply lost its trace id");
    let r = c
        .infer("fashion_syn", 14, 0.02, 0, 7, &xs)
        .unwrap();
    let infer_trace =
        u64::from_str_radix(r.req("trace").as_str(), 16).unwrap();
    assert_ne!(infer_trace, 0, "infer reply lost its trace id");
    assert_ne!(infer_trace, point_trace, "trace ids must be fresh");

    c.shutdown().unwrap();
    srv.join().unwrap();

    // export exactly what `--trace` writes, then re-read the file
    let path =
        std::path::Path::new(&run_dir).join("loopback.trace.json");
    obs::trace::write_trace(&path).unwrap();
    let j =
        Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();

    // Chrome-trace shape: complete events carry the mandatory keys
    let raw = j.req("traceEvents").as_arr();
    assert!(!raw.is_empty(), "trace exported no events");
    for e in raw {
        if e.req("ph").as_str() != "X" {
            continue;
        }
        for key in ["pid", "tid", "ts", "dur", "name"] {
            assert!(
                e.get(key).is_some(),
                "event missing `{key}`: {e}"
            );
        }
    }

    let evs = obs::trace::parse_chrome_trace(&j).unwrap();
    let all_spans: HashSet<u64> =
        evs.iter().map(|e| e.span).collect();
    let of = |t: u64| -> Vec<&obs::trace::TraceEv> {
        evs.iter().filter(|e| e.trace == t).collect()
    };

    // the infer's trace links queue -> batch -> forward -> reply (the
    // lone in-flight infer makes its trace the batch's home trace)
    let infer_evs = of(infer_trace);
    for want in
        ["serve.queue", "serve.batch", "backend.forward", "serve.reply"]
    {
        assert!(
            infer_evs.iter().any(|e| e.name == want),
            "missing `{want}` on the infer trace; got {:?}",
            infer_evs.iter().map(|e| &e.name).collect::<Vec<_>>()
        );
    }
    // nesting: every parent ref on the trace resolves inside the file
    let mut nested = 0;
    for e in &infer_evs {
        if e.parent != 0 {
            assert!(
                all_spans.contains(&e.parent),
                "span {} ({}) has dangling parent {}",
                e.span,
                e.name,
                e.parent
            );
            nested += 1;
        }
    }
    assert!(nested >= 1, "no nested spans on the infer trace");

    // across threads: the batcher records queue/reply, a pool worker
    // records the forward — at least two distinct tids per trace
    let hex = format!("{infer_trace:x}");
    let tids: HashSet<u64> = raw
        .iter()
        .filter(|e| {
            e.get("args")
                .and_then(|a| a.get("trace"))
                .map(|t| t.as_str() == hex)
                .unwrap_or(false)
        })
        .map(|e| e.req("tid").as_f64() as u64)
        .collect();
    assert!(
        tids.len() >= 2,
        "infer trace confined to one thread: tids {tids:?}"
    );

    // the point's trace carries the session-thread phases
    let point_evs = of(point_trace);
    for want in ["serve.queue", "serve.point", "serve.reply"] {
        assert!(
            point_evs.iter().any(|e| e.name == want),
            "missing `{want}` on the point trace; got {:?}",
            point_evs.iter().map(|e| &e.name).collect::<Vec<_>>()
        );
    }
    // the cold solve itself ran under the point's request trace
    assert!(
        evs.iter().any(|e| e.name == "session.solve"),
        "no session.solve span recorded"
    );

    let _ = std::fs::remove_dir_all(&run_dir);
}
