//! Backend-layer integration tests, fully offline: the native
//! inference path must be a bit-exact drop-in for the scalar engine
//! (and, when artifacts are present on an `xla` build, for the AOT
//! eval artifacts — see the gated module at the bottom).

use capmin::backend::arch::{model_meta, model_names};
use capmin::backend::kernels::{self, KernelKind};
use capmin::backend::native::{init_folded, NativeBackend};
use capmin::backend::InferenceBackend;
use capmin::bnn::{BitMatrix, ErrorModel, SubMacEngine};
use capmin::capmin::Fmac;
use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::session::{DesignSession, OperatingPointSpec};
use capmin::util::pool::ScopedPool;
use capmin::util::rng::Rng;

mod common;
use common::kernel_tiers as tiers;

fn rand_pm(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.pm1(0.5)).collect()
}

fn random_error_model(rng: &mut Rng) -> ErrorModel {
    let mut full = vec![vec![0.0f64; 33]; 33];
    for (m, row) in full.iter_mut().enumerate() {
        let mut tot = 0.0;
        for d in -2i64..=2 {
            let j = (m as i64 + d).clamp(0, 32) as usize;
            let w = rng.f64() + 0.05;
            row[j] += w;
            tot += w;
        }
        row.iter_mut().for_each(|v| *v /= tot);
    }
    ErrorModel::from_full(&full)
}

/// Property test (satellite: kernel-dispatch bit-equality): every
/// kernel tier, single-thread and thread-pooled, is bit-identical to
/// the scalar `SubMacEngine` matmul+decode across random shapes,
/// ragged reduction lengths (packed widths that are and are not
/// multiples of 64), error models and seeds — scalar == SIMD ==
/// threaded.
#[test]
fn native_kernels_bit_identical_to_submac_engine() {
    let mut rng = Rng::new(0xBE);
    for trial in 0..25 {
        let o = 1 + rng.below(24) as usize;
        // 1..=8 groups of 32: odd counts exercise the phantom u64 half
        let k = 32 * (1 + rng.below(8) as usize);
        let d = 1 + rng.below(300) as usize;
        let w = rand_pm(&mut rng, o * k);
        let x = rand_pm(&mut rng, d * k);
        // ragged beta: engine subtracts fewer cells than packed width
        let beta = k - rng.below(20) as usize;
        let eng = SubMacEngine::new(o, k, &w, beta);
        let xb = BitMatrix::pack(d, k, &x, false);
        let em = random_error_model(&mut rng);
        let seed = rng.next_u32();
        let salt = rng.next_u32();
        let want_err = eng.matmul_error(&xb, &em, seed, salt);
        let want_exact = eng.matmul_exact(&xb);
        let want_hist = eng.histogram(&xb);
        let threads = 1 + rng.below(7) as usize;
        let pool = ScopedPool::new(threads);
        let seq = ScopedPool::sequential();
        for kind in tiers() {
            assert_eq!(
                kernels::matmul_error(
                    &seq, &eng, &xb, &em, seed, salt, kind
                ),
                want_err,
                "{} error mismatch at trial {trial}",
                kind.name()
            );
            assert_eq!(
                kernels::matmul_error(
                    &pool, &eng, &xb, &em, seed, salt, kind
                ),
                want_err,
                "{} threaded error mismatch at trial {trial} \
                 ({threads} threads)",
                kind.name()
            );
            assert_eq!(
                kernels::matmul_exact(&pool, &eng, &xb, kind),
                want_exact,
                "{} exact mismatch at trial {trial}",
                kind.name()
            );
            let (out, hist) =
                kernels::matmul_exact_fused(&pool, &eng, &xb, kind);
            assert_eq!(
                out,
                want_exact,
                "{} fused out mismatch at trial {trial}",
                kind.name()
            );
            assert_eq!(
                hist,
                want_hist,
                "{} fused hist mismatch at trial {trial}",
                kind.name()
            );
        }
    }
}

/// The fused matmul+histogram path reproduces
/// `SubMacEngine::histogram` exactly on a smoke input, at every pool
/// size and tier (the CI no-XLA job runs this by name).
#[test]
fn fused_histogram_matches_engine() {
    let mut rng = Rng::new(0xF0);
    let (o, k, d) = (8usize, 160usize, 97usize);
    let w = rand_pm(&mut rng, o * k);
    let x = rand_pm(&mut rng, d * k);
    let eng = SubMacEngine::new(o, k, &w, k - 7);
    let xb = BitMatrix::pack(d, k, &x, false);
    let want_hist = eng.histogram(&xb);
    let want_out = eng.matmul_exact(&xb);
    for kind in tiers() {
        for threads in [1usize, 2, 3, 8, 32] {
            let pool = ScopedPool::new(threads);
            let (out, hist) =
                kernels::matmul_exact_fused(&pool, &eng, &xb, kind);
            assert_eq!(
                hist,
                want_hist,
                "{} hist at {threads} threads",
                kind.name()
            );
            assert_eq!(
                out,
                want_out,
                "{} out at {threads} threads",
                kind.name()
            );
            // and the separate histogram kernel agrees too
            assert_eq!(
                kernels::histogram(&pool, &eng, &xb, kind),
                want_hist,
                "{} separate hist at {threads} threads",
                kind.name()
            );
        }
    }
}

/// Whole-model logits are independent of the kernel fan-out.
#[test]
fn native_logits_independent_of_thread_count() {
    for model in ["vgg3_tiny", "vgg3"] {
        let folded = init_folded(model).unwrap();
        let meta = model_meta(model).unwrap();
        let px: usize = meta.in_shape.iter().product();
        let b = 2usize;
        let mut rng = Rng::new(7);
        let x = rand_pm(&mut rng, b * px);
        let ems: Vec<ErrorModel> = (0..meta.n_matmuls())
            .map(|_| random_error_model(&mut rng))
            .collect();
        let reference = NativeBackend::new(1)
            .logits(model, &folded, &x, b, &ems, 99)
            .unwrap();
        for threads in [2usize, 5] {
            let got = NativeBackend::new(threads)
                .logits(model, &folded, &x, b, &ems, 99)
                .unwrap();
            assert_eq!(got, reference, "{model} at {threads} threads");
        }
    }
}

/// Every registry model runs a forward pass (shape walk, folded
/// signature and op dispatch all agree) — including the resnet18 skip
/// blocks.
#[test]
fn every_model_forward_passes() {
    for model in model_names() {
        let folded = init_folded(model).unwrap();
        let meta = model_meta(model).unwrap();
        let px: usize = meta.in_shape.iter().product();
        let mut rng = Rng::new(3);
        let x = rand_pm(&mut rng, px);
        let ems: Vec<ErrorModel> = (0..meta.n_matmuls())
            .map(|_| ErrorModel::identity())
            .collect();
        let logits = NativeBackend::new(2)
            .logits(model, &folded, &x, 1, &ems, 0)
            .unwrap();
        assert_eq!(logits.len(), meta.n_classes, "{model}");
        assert!(logits.iter().all(|v| v.is_finite()), "{model}");
    }
}

/// Whole-model F_MAC extraction agrees between the fused single-pass
/// data flow and the pre-fusion two-pass one, across tiers and
/// thread counts.
#[test]
fn fused_fmac_matches_unfused_end_to_end() {
    let model = "vgg3_tiny";
    let folded = init_folded(model).unwrap();
    let spec = Dataset::FashionSyn.spec();
    let want = NativeBackend::with_options(1, KernelKind::Scalar, false)
        .fmac(model, &folded, spec.clone(), 16, 9)
        .unwrap();
    for kind in tiers() {
        for (threads, fused) in [(1usize, true), (3, true), (3, false)]
        {
            let be = NativeBackend::with_options(threads, kind, fused);
            let got =
                be.fmac(model, &folded, spec.clone(), 16, 9).unwrap();
            assert_eq!(
                got.per_matmul,
                want.per_matmul,
                "{} threads={threads} fused={fused}",
                kind.name()
            );
            assert_eq!(got.sum, want.sum);
            assert_eq!(got.accuracy, want.accuracy);
        }
    }
}

/// Native F_MAC extraction: deterministic, correctly shaped, and the
/// per-matmul histograms sum to the expected group count per sample.
#[test]
fn native_fmac_is_deterministic_and_consistent() {
    let model = "vgg3_tiny";
    let folded = init_folded(model).unwrap();
    let spec = Dataset::FashionSyn.spec();
    let be = NativeBackend::new(2);
    let a = be.fmac(model, &folded, spec.clone(), 16, 9).unwrap();
    let b = be.fmac(model, &folded, spec.clone(), 16, 9).unwrap();
    let meta = model_meta(model).unwrap();
    assert_eq!(a.per_matmul.len(), meta.n_matmuls());
    assert_eq!(a.per_matmul, b.per_matmul);
    assert_eq!(a.accuracy, b.accuracy);
    assert!(a.sum.total() > 0);
    let merged: Fmac = {
        let mut f = Fmac::new();
        for m in &a.per_matmul {
            f.merge(m);
        }
        f
    };
    assert_eq!(merged, a.sum);
    assert!((0.0..=1.0).contains(&a.accuracy));
    assert_eq!(a.n_samples, 16);
}

fn offline_native_session(tag: &str) -> Option<(DesignSession, String)> {
    // skip when an xla build could reach real artifacts: these tests
    // exercise the no-XLA path (training there would be slow and
    // redundant with tests/integration.rs)
    if cfg!(feature = "xla")
        && capmin::runtime::artifacts_dir()
            .join("manifest.json")
            .exists()
    {
        return None;
    }
    let dir = std::env::temp_dir()
        .join(format!(
            "capmin_backend_test_{tag}_{}",
            std::process::id()
        ))
        .to_str()
        .unwrap()
        .to_string();
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    cfg.mc_samples = 100;
    cfg.hist_limit = 32;
    cfg.eval_limit = 16;
    cfg.run_dir = dir.clone();
    let session = DesignSession::builder().config(cfg).build().unwrap();
    Some((session, dir))
}

/// The full codesign query — F_MAC extraction, hardware solve and
/// accuracy evaluation — runs end-to-end on the native backend with no
/// artifacts, no training and no XLA, and records its provenance.
#[test]
fn session_answers_evaluated_queries_natively() {
    let Some((session, dir)) = offline_native_session("e2e") else {
        eprintln!("skipping: artifacts present, covered by integration");
        return;
    };
    let ds = Dataset::FashionSyn;
    let spec = OperatingPointSpec::new(ds, 14, 0.02, 0).with_eval(1, 1);
    let point = session.query(&spec).unwrap();
    let acc = point.accuracy.expect("eval requested");
    assert!((0.0..=1.0).contains(&acc));
    assert!(point.c > 0.0);
    assert_eq!(point.meta.backend, "native");
    // `--threads` unset (0) resolves through available_parallelism:
    // the recorded count is the resolved one, never a literal 0
    assert_eq!(point.meta.threads, session.threads());
    assert!(point.meta.threads >= 1, "unresolved thread count in meta");
    // the resolved kernel tier is recorded alongside it
    assert_eq!(point.meta.kernel, KernelKind::detect().name());
    assert_eq!(session.kernel_name(), KernelKind::detect().name());
    // ... and so is the resolved register-blocking tile (DESIGN.md
    // §14): non-empty provenance matching the session's resolution,
    // with the `auto` measurement persisted in the run's autotune cache
    assert!(!point.meta.tile.is_empty(), "tile missing from meta");
    assert_eq!(point.meta.tile, session.tile_name());
    assert!(
        session.store().path("autotune.json").exists(),
        "`--tile auto` must persist its measurement"
    );
    assert!(
        session.is_untrained(ds),
        "cold store without XLA must flag the untrained fallback"
    );
    // the untrained fallback must never pollute the run store caches —
    // neither the folded/F_MAC stage files nor the on-disk point cache
    // (its key doesn't encode model content, so trained runs would
    // replay the near-chance accuracy)
    assert!(!session
        .store()
        .path(&format!("{}_folded.capt", ds.spec().name))
        .exists());
    assert!(!session
        .store()
        .path(&format!("{}_fmac.capt", ds.spec().name))
        .exists());
    assert!(!session
        .store()
        .path("points")
        .join(format!("{}.json", spec.cache_key(session.config())))
        .exists());
    // but the operating point itself memoizes in memory and replays
    let replay = session.query(&spec).unwrap();
    assert_eq!(*replay, *point);
    assert_eq!(session.stats().evals, 1, "replay served from memory");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Batched and sequential native queries agree exactly (thread
/// scheduling cannot change an answer), including evaluated points.
#[test]
fn native_query_many_matches_sequential() {
    let Some((seq, dir_a)) = offline_native_session("seq") else {
        return;
    };
    let Some((par, dir_b)) = offline_native_session("par") else {
        return;
    };
    let specs: Vec<OperatingPointSpec> = [32usize, 14, 8]
        .iter()
        .map(|&k| {
            OperatingPointSpec::new(Dataset::FashionSyn, k, 0.02, 0)
                .with_eval(1, 1)
        })
        .collect();
    let a: Vec<_> = specs.iter().map(|s| seq.query(s).unwrap()).collect();
    let b = par.query_many(&specs).unwrap();
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(**x, **y);
    }
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

/// Bit-exact cross-backend checks against the AOT artifacts (the
/// native path is a drop-in for the eval artifact, not an
/// approximation). Requires `make artifacts` + `--features xla`.
#[cfg(feature = "xla")]
mod xla_equivalence {
    use super::*;
    use capmin::backend::XlaBackend;
    use capmin::coordinator::store::NamedTensor;
    use capmin::runtime::{artifacts_dir, lit_u32, to_f32, Runtime};
    use std::sync::Arc;

    fn runtime() -> Option<Arc<Runtime>> {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping xla equivalence: run `make artifacts`");
            return None;
        }
        Some(Arc::new(Runtime::new().unwrap()))
    }

    /// init + export vgg3_tiny through the artifacts, then compare
    /// whole-model logits: native backend vs both eval engines,
    /// bit for bit, under stochastic error models.
    #[test]
    fn native_logits_match_eval_artifacts_bit_exact() {
        let Some(rt) = runtime() else { return };
        let model = "vgg3_tiny";
        let mi = rt.manifest.model(model).clone();
        let init = rt.load(model, "init").unwrap();
        let export = rt.load(model, "export").unwrap();
        let key = lit_u32(&[2], &[1, 2]).unwrap();
        let ps = init.run(&[key]).unwrap();
        let folded_lits = export.run(&ps).unwrap();
        let folded: Vec<NamedTensor> = folded_lits
            .iter()
            .zip(mi.artifacts["export"].outputs.iter())
            .map(|(lit, sig)| NamedTensor {
                name: sig.name.clone(),
                shape: sig.shape.clone(),
                data: to_f32(lit).unwrap(),
            })
            .collect();

        let mut rng = Rng::new(6);
        let eb = mi.eval_batch;
        let px: usize = mi.in_shape.iter().product();
        let x = rand_pm(&mut rng, eb * px);
        let ems: Vec<ErrorModel> = (0..mi.n_matmuls)
            .map(|_| random_error_model(&mut rng))
            .collect();

        let native = NativeBackend::new(3);
        for seed in [0u32, 99, 0xDEAD_BEEF] {
            let a = native
                .logits(model, &folded, &x, eb, &ems, seed)
                .unwrap();
            for engine in ["eval", "evalp"] {
                let xla_be = XlaBackend::new(rt.clone(), engine);
                let b = xla_be
                    .logits(model, &folded, &x, eb, &ems, seed)
                    .unwrap();
                assert_eq!(
                    a, b,
                    "native vs {engine} logits diverge at seed {seed}"
                );
            }
        }
    }

    /// F_MAC histograms and clean accuracy agree between the native
    /// path and the hist artifact.
    #[test]
    fn native_fmac_matches_hist_artifact() {
        let Some(rt) = runtime() else { return };
        let model = "vgg3_tiny";
        let mi = rt.manifest.model(model).clone();
        let init = rt.load(model, "init").unwrap();
        let export = rt.load(model, "export").unwrap();
        let ps = init.run(&[lit_u32(&[2], &[3, 4]).unwrap()]).unwrap();
        let folded_lits = export.run(&ps).unwrap();
        let folded: Vec<NamedTensor> = folded_lits
            .iter()
            .zip(mi.artifacts["export"].outputs.iter())
            .map(|(lit, sig)| NamedTensor {
                name: sig.name.clone(),
                shape: sig.shape.clone(),
                data: to_f32(lit).unwrap(),
            })
            .collect();
        let spec = Dataset::FashionSyn.spec();
        let native = NativeBackend::new(2)
            .fmac(model, &folded, spec.clone(), 32, 11)
            .unwrap();
        let xla = XlaBackend::new(rt.clone(), "eval")
            .fmac(model, &folded, spec, 32, 11)
            .unwrap();
        assert_eq!(native.per_matmul, xla.per_matmul);
        assert_eq!(native.sum, xla.sum);
        assert_eq!(native.accuracy, xla.accuracy);
    }
}
