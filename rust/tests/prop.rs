//! Property-based tests over the substrates' invariants (hand-rolled
//! driver — no proptest offline; DESIGN.md §8). Each property runs many
//! randomized cases from a deterministic seed and reports the failing
//! case's seed on panic.

use capmin::analog::capacitor::{CapacitorModel, CapacitorSolver};
use capmin::analog::montecarlo::MonteCarlo;
use capmin::analog::neuron::SpikeTimeSet;
use capmin::analog::params::AnalogParams;
use capmin::analog::pmap::{to_cdf_inputs, Pmap};
use capmin::analog::{clock, rc};
use capmin::bnn::{BitMatrix, ErrorModel, SubMacEngine};
use capmin::capmin::capmin::select_window;
use capmin::capmin::capmin_v::capmin_v;
use capmin::capmin::Fmac;
use capmin::data::synth::Dataset;
use capmin::session::point::OperatingPoint;
use capmin::session::solver::solve;
use capmin::session::OperatingPointSpec;
use capmin::util::json::Json;
use capmin::util::rng::Rng;

mod common;

/// Mini property-test driver: `cases` randomized executions, seed
/// reported on failure.
fn forall(name: &str, cases: usize, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xBA5E_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)),
        );
        if let Err(e) = result {
            eprintln!("property `{name}` failed at seed {seed:#x}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_fmac(rng: &mut Rng) -> Fmac {
    // unimodal histogram with a random peak and sharpness
    let peak = 4 + rng.below(25) as usize;
    let sharp = 1.5 + 5.0 * rng.f64();
    Fmac::gaussian(peak, sharp, 1e9)
}

fn random_pmap(rng: &mut Rng, lo: usize, k: usize) -> Pmap {
    let levels: Vec<usize> = (lo..lo + k).collect();
    let p: Vec<Vec<f64>> = (0..k)
        .map(|_| {
            let mut row: Vec<f64> =
                (0..k).map(|_| rng.f64() + 1e-3).collect();
            let s: f64 = row.iter().sum();
            row.iter_mut().for_each(|v| *v /= s);
            row
        })
        .collect();
    Pmap { levels, p }
}

#[test]
fn prop_capacitor_monotone_in_window_top() {
    let p = AnalogParams::paper_calibrated();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    forall("cap monotone", 200, |rng| {
        let hi = 2 + rng.below(31) as usize;
        let lo = 1 + rng.below(hi as u64 - 1) as usize;
        let c1 = solver.size_for_window(lo, hi);
        let c2 = solver.size_for_window(lo, (hi + 1).min(32));
        assert!(c2 >= c1, "C must grow with q_hi: [{lo},{hi}]");
        // and sizing is independent of q_lo (top-dominated)
        let c3 = solver.size_for_window(1, hi);
        assert!((c3 - c1).abs() < 1e-18);
    });
}

#[test]
fn prop_sized_window_always_feasible() {
    let p = AnalogParams::paper_calibrated();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    forall("sized window feasible", 100, |rng| {
        let hi = 2 + rng.below(31) as usize;
        let lo = 1 + rng.below(hi as u64 - 1) as usize;
        let c = solver.size_for_window(lo, hi);
        let set = SpikeTimeSet::new(&p, c, (lo..=hi).collect());
        assert!(set.distinct(&p), "[{lo},{hi}] at sized C");
    });
}

#[test]
fn prop_select_window_contains_peak_and_is_width_k() {
    forall("window contains peak", 300, |rng| {
        let f = random_fmac(rng);
        let peak = (1..33)
            .max_by_key(|&m| f.counts[m])
            .unwrap();
        let k = 1 + rng.below(32) as usize;
        let w = select_window(&f, k);
        assert_eq!(w.q_hi - w.q_lo + 1, k);
        assert!(w.q_lo >= 1 && w.q_hi <= 32);
        if k >= 3 {
            assert!(
                w.q_lo <= peak && peak <= w.q_hi,
                "window {w:?} must contain peak {peak}"
            );
        }
    });
}

#[test]
fn prop_select_window_coverage_monotone_in_k() {
    forall("coverage monotone", 100, |rng| {
        let f = random_fmac(rng);
        let mut prev = -1.0;
        for k in 1..=32 {
            let w = select_window(&f, k);
            assert!(
                w.coverage >= prev - 1e-12,
                "coverage must grow with k ({k})"
            );
            prev = w.coverage;
        }
        // k=32 covers exactly the mass of spike-bearing levels 1..=32
        // (level 0 never has a spike time and is clipped by design)
        let pmf = f.pmf();
        let spike_mass: f64 = pmf[1..].iter().sum();
        assert!(
            (prev - spike_mass).abs() < 1e-9,
            "k=32 coverage {prev} vs spike mass {spike_mass}"
        );
    });
}

#[test]
fn prop_capmin_v_preserves_stochasticity_and_improves_min_diag() {
    forall("capmin-v invariants", 200, |rng| {
        let k = 4 + rng.below(12) as usize;
        let lo = 1 + rng.below((33 - k) as u64 - 1) as usize;
        let pm = random_pmap(rng, lo, k);
        let phi = 1 + rng.below(k as u64 - 1) as usize;
        let before_min = pm
            .diag()
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        let res = capmin_v(pm, phi);
        assert_eq!(res.levels.len(), k - phi);
        for s in res.pmap.row_sums() {
            assert!((s - 1.0).abs() < 1e-9);
        }
        let after_min = res
            .pmap
            .diag()
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(after_min >= before_min - 1e-12);
        // surviving levels are a subset of the originals, still sorted
        assert!(res.levels.windows(2).all(|w| w[0] < w[1]));
    });
}

#[test]
fn prop_cdf_inputs_well_formed_for_any_padded_pmap() {
    forall("cdf well-formed", 200, |rng| {
        let k = 2 + rng.below(14) as usize;
        let lo = 1 + rng.below((33 - k) as u64 - 1) as usize;
        let mut pm = random_pmap(rng, lo, k);
        for _ in 0..rng.below(3) {
            if pm.k() > 2 {
                let j = pm.argmin_diag();
                let dst = if j == 0 { 1 } else { j - 1 };
                pm.merge_into(j, dst);
            }
        }
        let (cdf, vals) = to_cdf_inputs(&pm.pad_to_full());
        assert_eq!(vals.len(), 33);
        for m in 0..33 {
            let row = &cdf[m * 33..(m + 1) * 33];
            assert_eq!(row[32], 1.0);
            for j in 1..33 {
                assert!(row[j] >= row[j - 1]);
            }
        }
    });
}

#[test]
fn prop_engine_exact_equals_dense_dot() {
    forall("engine == dense", 60, |rng| {
        let o = 1 + rng.below(12) as usize;
        let k = 1 + rng.below(200) as usize;
        let d = 1 + rng.below(20) as usize;
        let kp = k.div_ceil(32) * 32;
        let mut w = vec![1.0f32; o * kp];
        let mut x = vec![-1.0f32; d * kp];
        for oi in 0..o {
            for ki in 0..k {
                w[oi * kp + ki] = rng.pm1(0.5);
            }
        }
        for di in 0..d {
            for ki in 0..k {
                x[di * kp + ki] = rng.pm1(0.5);
            }
        }
        let eng = SubMacEngine::new(o, kp, &w, k);
        let xb = BitMatrix::pack(d, kp, &x, false);
        let got = eng.matmul_exact(&xb);
        for oi in 0..o {
            for di in 0..d {
                let mut dot = 0.0f32;
                for ki in 0..k {
                    dot += w[oi * kp + ki] * x[di * kp + ki];
                }
                assert_eq!(got[oi * d + di], dot, "({oi},{di})");
            }
        }
    });
}

/// Satellite property: packed-vs-unpacked sub-MAC equality across
/// ragged widths — true reduction lengths whose packed width is *not*
/// a multiple of 64 (odd group counts leave a phantom u64 half), with
/// k in 1..=8 groups — through every kernel tier and a random pool
/// size, against the unpacked dense dot product.
#[test]
fn prop_packed_kernels_equal_unpacked_dense_across_ragged_widths() {
    use capmin::backend::kernels;
    use capmin::util::pool::ScopedPool;
    forall("packed kernels == dense (ragged)", 40, |rng| {
        let o = 1 + rng.below(10) as usize;
        // 1..=8 groups: the odd counts give packed widths that are
        // not multiples of 64 (phantom u64 half)
        let groups = 1 + rng.below(8) as usize;
        let kp = groups * 32;
        // ragged true length within the last group
        let k = kp - rng.below(31) as usize;
        let d = 1 + rng.below(40) as usize;
        let mut w = vec![1.0f32; o * kp];
        let mut x = vec![-1.0f32; d * kp];
        for oi in 0..o {
            for ki in 0..k {
                w[oi * kp + ki] = rng.pm1(0.5);
            }
        }
        for di in 0..d {
            for ki in 0..k {
                x[di * kp + ki] = rng.pm1(0.5);
            }
        }
        let eng = SubMacEngine::new(o, kp, &w, k);
        let xb = BitMatrix::pack(d, kp, &x, false);
        let mut dense = vec![0.0f32; o * d];
        for oi in 0..o {
            for di in 0..d {
                let mut dot = 0.0f32;
                for ki in 0..k {
                    dot += w[oi * kp + ki] * x[di * kp + ki];
                }
                dense[oi * d + di] = dot;
            }
        }
        let pool = ScopedPool::new(1 + rng.below(8) as usize);
        for kind in common::kernel_tiers() {
            assert_eq!(
                kernels::matmul_exact(&pool, &eng, &xb, kind),
                dense,
                "{} o={o} k={k} kp={kp} d={d}",
                kind.name()
            );
            let (out, hist) =
                kernels::matmul_exact_fused(&pool, &eng, &xb, kind);
            assert_eq!(out, dense, "fused {}", kind.name());
            assert_eq!(
                hist.iter().sum::<u64>(),
                (o * d * groups) as u64,
                "fused hist total {}",
                kind.name()
            );
        }
    });
}

/// Satellite property (PR 7, DESIGN.md §14): the register-blocked
/// packed path is bit-identical to the per-word kernels *and* the
/// naive unpacked dense dot product across ragged shapes — packed
/// widths that are not multiples of 64 (odd group counts), o smaller
/// than MR, d smaller than NR — under random tiles, at every
/// supported tier and a random thread count, fused and unfused.
#[test]
fn prop_blocked_tiled_equals_word_and_dense_across_ragged_shapes() {
    use capmin::backend::kernels::{self, ResolvedTile, Tile};
    use capmin::util::pool::ScopedPool;
    forall("blocked == word == dense (ragged)", 40, |rng| {
        let o = 1 + rng.below(10) as usize;
        let groups = 1 + rng.below(8) as usize;
        let kp = groups * 32;
        let k = kp - rng.below(31) as usize;
        let d = 1 + rng.below(40) as usize;
        let mut w = vec![1.0f32; o * kp];
        let mut x = vec![-1.0f32; d * kp];
        for oi in 0..o {
            for ki in 0..k {
                w[oi * kp + ki] = rng.pm1(0.5);
            }
        }
        for di in 0..d {
            for ki in 0..k {
                x[di * kp + ki] = rng.pm1(0.5);
            }
        }
        let eng = SubMacEngine::new(o, kp, &w, k);
        let xb = BitMatrix::pack(d, kp, &x, false);
        let mut dense = vec![0.0f32; o * d];
        for oi in 0..o {
            for di in 0..d {
                let mut dot = 0.0f32;
                for ki in 0..k {
                    dot += w[oi * kp + ki] * x[di * kp + ki];
                }
                dense[oi * d + di] = dot;
            }
        }
        let lane = |rng: &mut Rng| {
            Tile::LANES[rng.below(Tile::LANES.len() as u64) as usize]
        };
        let tile =
            Tile::new(lane(rng), lane(rng), 1 + rng.below(8) as usize);
        let blocked = ResolvedTile::Blocked(tile);
        let pool = ScopedPool::new(1 + rng.below(8) as usize);
        let shape = format!(
            "tile {} o={o} k={k} kp={kp} d={d}",
            tile.name()
        );
        for kind in common::kernel_tiers() {
            let word = kernels::matmul_exact(&pool, &eng, &xb, kind);
            assert_eq!(word, dense, "word {} {shape}", kind.name());
            assert_eq!(
                kernels::matmul_exact_tiled(
                    &pool, &eng, &xb, kind, blocked
                ),
                dense,
                "blocked {} {shape}",
                kind.name()
            );
            let (wout, whist) =
                kernels::matmul_exact_fused(&pool, &eng, &xb, kind);
            let (bout, bhist) = kernels::matmul_exact_fused_tiled(
                &pool, &eng, &xb, kind, blocked,
            );
            assert_eq!(wout, dense, "fused word {} {shape}", kind.name());
            assert_eq!(
                bout,
                dense,
                "fused blocked {} {shape}",
                kind.name()
            );
            assert_eq!(
                bhist,
                whist,
                "fused hist {} {shape}",
                kind.name()
            );
        }
    });
}

#[test]
fn prop_error_model_decode_matches_row_distribution() {
    forall("decode ~ matrix row", 20, |rng| {
        let em = {
            let mut full = vec![vec![0.0f64; 33]; 33];
            for (m, row) in full.iter_mut().enumerate() {
                let spread = 1 + rng.below(3) as i64;
                let mut total = 0.0;
                for d in -spread..=spread {
                    let j = (m as i64 + d).clamp(0, 32) as usize;
                    let w = rng.f64() + 0.1;
                    row[j] += w;
                    total += w;
                }
                row.iter_mut().for_each(|v| *v /= total);
            }
            ErrorModel::from_full(&full)
        };
        // empirical frequency of decode(m, u) over uniform u
        let m = rng.below(33) as usize;
        let n = 20_000;
        let mut counts = [0usize; 33];
        let mut r2 = rng.split(1);
        for _ in 0..n {
            let u = r2.f32();
            counts[em.decode(m, u) as usize] += 1;
        }
        for j in 0..33 {
            let want = em.cdf[m * 33 + j]
                - if j > 0 { em.cdf[m * 33 + j - 1] } else { 0.0 };
            let got = counts[j] as f32 / n as f32;
            assert!(
                (got - want).abs() < 0.02,
                "level {m}->{j}: want {want} got {got}"
            );
        }
    });
}

#[test]
fn prop_spike_decode_roundtrip_with_clipping() {
    let p = AnalogParams::paper_calibrated();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    forall("decode == clip", 100, |rng| {
        let hi = 3 + rng.below(30) as usize;
        let lo = 1 + rng.below(hi as u64 - 2) as usize;
        let c = solver.size_for_window(lo, hi);
        let set = SpikeTimeSet::new(&p, c, (lo..=hi).collect());
        for m in 0..=32usize {
            let t = clock::quantize(&p, rc::level_spike_time(&p, c, m));
            assert_eq!(
                set.decode(t),
                m.clamp(lo, hi),
                "level {m} window [{lo},{hi}]"
            );
        }
    });
}

#[test]
fn prop_window_capacitor_demand_monotone_in_k() {
    // the CapMin guarantee behind Fig. 9: shrinking k never *raises*
    // the capacitor demand — on the (unimodal) F_MACs the framework
    // sees, the selected window's q_hi grows monotonically with k, and
    // the shared capacitor is sized by q_hi alone
    let p = AnalogParams::paper_calibrated();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    forall("q_hi monotone in k", 200, |rng| {
        let f = random_fmac(rng);
        let mut prev_hi = 0usize;
        let mut prev_c = 0.0f64;
        for k in 1..=32 {
            let w = select_window(&f, k);
            assert!(
                w.q_hi >= prev_hi,
                "demand q_hi dropped going up to k={k}: {w:?}"
            );
            let c = solver.size_for_window(w.q_lo, w.q_hi);
            assert!(
                c >= prev_c,
                "capacitor demand dropped going up to k={k}"
            );
            prev_hi = w.q_hi;
            prev_c = c;
        }
    });
}

#[test]
fn prop_fmac_merge_preserves_totals() {
    forall("merge totals", 100, |rng| {
        let a = random_fmac(rng);
        let b = random_fmac(rng);
        let (ta, tb) = (a.total(), b.total());
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.total(), ta + tb);
        for lvl in 0..33 {
            assert_eq!(m.counts[lvl], a.counts[lvl] + b.counts[lvl]);
        }
    });
}

#[test]
fn prop_combine_normalized_preserves_normalization() {
    forall("combine normalization", 100, |rng| {
        let n = 2 + rng.below(4) as usize;
        let fmacs: Vec<Fmac> =
            (0..n).map(|_| random_fmac(rng)).collect();
        let refs: Vec<&Fmac> = fmacs.iter().collect();
        let comb = Fmac::combine_normalized(&refs);
        // each benchmark contributes exactly unit mass
        let total: f64 = comb.iter().sum();
        assert!(
            (total - n as f64).abs() < 1e-9,
            "combined mass {total} != {n}"
        );
        assert!(comb.iter().all(|&v| v >= 0.0));
        // and each pmf itself sums to one
        for f in &fmacs {
            let s: f64 = f.pmf().iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_operating_point_json_roundtrips() {
    let p = AnalogParams::paper_calibrated();
    forall("point json roundtrip", 25, |rng| {
        let n_mat = 1 + rng.below(3) as usize;
        let fmacs: Vec<Fmac> =
            (0..n_mat).map(|_| random_fmac(rng)).collect();
        let k = 4 + rng.below(28) as usize;
        let sigma = if rng.below(2) == 0 { 0.0 } else { 0.03 };
        let phi = rng.below(3) as usize;
        let mut spec =
            OperatingPointSpec::new(Dataset::SvhnSyn, k, sigma, phi);
        if rng.below(2) == 0 {
            spec = spec.with_eval(rng.below(1000) as u32, 3);
        }
        let hw = solve(
            p,
            7,
            capmin::analog::McSettings::paper(100),
            1,
            &fmacs,
            k,
            sigma,
            phi,
        );
        let accuracy =
            if spec.eval.is_some() { Some(rng.f64()) } else { None };
        let point = OperatingPoint::from_solve(
            spec,
            hw,
            accuracy,
            Default::default(),
        );
        let text = point.to_json().to_string();
        let back = OperatingPoint::from_json(
            &Json::parse(&text).expect("written JSON parses"),
        )
        .expect("written JSON loads");
        assert_eq!(point, back, "round-trip must be exact");
    });
}

#[test]
fn prop_mc_pmap_diag_improves_with_smaller_sigma() {
    let p = AnalogParams::paper_calibrated();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    forall("diag vs sigma", 10, |rng| {
        let hi = 20 + rng.below(12) as usize;
        let lo = hi - 10;
        let c = solver.size_for_window(lo, hi);
        let mean_diag = |sigma: f64, rng: &mut Rng| {
            let pp = p.with_sigma(sigma);
            let set = SpikeTimeSet::new(&pp, c, (lo..=hi).collect());
            let mc = MonteCarlo::new(pp).with_samples(400);
            let pm = mc.pmap(&set, rng);
            let d = pm.diag();
            d.iter().sum::<f64>() / d.len() as f64
        };
        let d_small = mean_diag(0.005, rng);
        let d_large = mean_diag(0.08, rng);
        assert!(
            d_small > d_large,
            "less variation -> better diagonal ({d_small} vs {d_large})"
        );
    });
}

#[test]
fn prop_cost_energy_and_area_monotone_in_c() {
    use capmin::analog::cost::{cost, CostVector};
    let p = AnalogParams::paper_calibrated();
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    forall("cost monotone in C", 150, |rng| {
        let hi = 2 + rng.below(31) as usize;
        let lo = 1 + rng.below(hi as u64 - 1) as usize;
        let c1 = solver.size_for_window(lo, hi);
        let c2 = c1 * (1.1 + rng.f64());
        let s1 = SpikeTimeSet::new(&p, c1, (lo..=hi).collect());
        let s2 = SpikeTimeSet::new(&p, c2, (lo..=hi).collect());
        let cv1 = CostVector::price(&p, c1, &[s1.times.clone()]);
        let cv2 = CostVector::price(&p, c2, &[s2.times.clone()]);
        assert!(
            cv2.energy > cv1.energy,
            "energy monotone: [{lo},{hi}]"
        );
        assert!(cv2.area > cv1.area, "area monotone: [{lo},{hi}]");
        // the per-set CircuitCost agrees on every ratio direction
        let (rc, re, _, ra) = cost(&p, &s1).ratio_vs(&cost(&p, &s2));
        assert!(rc >= 1.0 && re >= 1.0 && ra >= 1.0);
    });
}

#[test]
fn prop_frontier_subset_no_dominated_idempotent() {
    use capmin::util::pareto::{dominates, non_dominated};
    forall("pareto frontier", 300, |rng| {
        let d = 1 + rng.below(4) as usize;
        let n = 1 + rng.below(60) as usize;
        // coarse values force ties and duplicates often
        let vals: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                (0..d).map(|_| rng.below(6) as f64).collect()
            })
            .collect();
        let front = non_dominated(&vals);

        // a subset of its input, strictly ascending (no repeats)
        assert!(!front.is_empty(), "finite inputs always have a front");
        assert!(front.iter().all(|&i| i < n));
        assert!(front.windows(2).all(|w| w[0] < w[1]));

        // contains no dominated point
        for &i in &front {
            for &j in &front {
                assert!(
                    !dominates(&vals[i], &vals[j]),
                    "front member {i} dominates front member {j}"
                );
            }
        }
        // and excludes only dominated points
        for i in 0..n {
            if !front.contains(&i) {
                assert!(
                    front.iter().any(|&f| dominates(&vals[f], &vals[i])),
                    "excluded point {i} is not dominated"
                );
            }
        }

        // idempotent: the front of the front is the whole front
        let front_vals: Vec<Vec<f64>> =
            front.iter().map(|&i| vals[i].clone()).collect();
        let again = non_dominated(&front_vals);
        assert_eq!(again, (0..front.len()).collect::<Vec<_>>());
    });
}

#[test]
fn prop_cost_vector_json_roundtrip() {
    use capmin::analog::cost::CostVector;
    forall("cost vector json", 200, |rng| {
        let cv = CostVector {
            c: rng.f64() * 1e-10,
            spike_times: rng.below(500) as usize,
            energy: rng.f64() * 1e-12,
            area: rng.f64() * 1e-8,
            latency: rng.f64() * 1e-6,
        };
        let back = CostVector::from_json(
            &Json::parse(&cv.to_json().to_string())
                .expect("written JSON parses"),
        )
        .expect("written JSON loads");
        assert_eq!(cv, back, "round-trip must be exact");
    });
}
