//! Golden-file tests for the unified reporter (DESIGN.md §10): the
//! markdown/CSV/JSON renderings of two representative plans — table2
//! (registry-driven) and fig8 (solver + eval driven, offline on
//! injected F_MACs and the deterministic untrained fallback) — are
//! pinned byte-for-byte under `tests/golden/`, so formatting refactors
//! can't silently change artifacts.
//!
//! Bless protocol (this testbed has no network and goldens are
//! machine-independent by the backend's bit-identical contract): a
//! missing golden is written on first run, `UPDATE_GOLDEN=1` rewrites
//! it deliberately, and any later drift fails with a diff pointer. On
//! top of the byte comparison, every case asserts structure that must
//! hold even on a blessing run, and fig8 renders twice from two fresh
//! sessions to prove the bytes are reproducible at all.

use std::fs;
use std::path::PathBuf;

use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::experiments::fig8::Fig8Plan;
use capmin::experiments::tables::Table2Plan;
use capmin::plan::report::Emit;
use capmin::plan::ExperimentPlan;
use capmin::session::DesignSession;
use capmin::util::json::Json;

mod common;
use common::{artifacts_present, inject_fmacs, tmp_dir};

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(name)
}

/// Compare against (or bless) `tests/golden/<name>`.
fn check_golden(name: &str, content: &str) {
    let path = golden_path(name);
    let bless = std::env::var("UPDATE_GOLDEN").is_ok();
    if bless || !path.exists() {
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, content).unwrap();
        eprintln!("blessed golden {name}");
        return;
    }
    let want = fs::read_to_string(&path).unwrap();
    assert_eq!(
        want, content,
        "golden drift in {name}: rerun with UPDATE_GOLDEN=1 if the \
         change is intentional"
    );
}

#[test]
fn table2_report_matches_golden() {
    if artifacts_present() {
        // the manifest-backed table differs per artifact build
        eprintln!("skipping: artifacts present");
        return;
    }
    let dir = tmp_dir("golden_table2");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    cfg.run_dir = dir.clone();
    let session = DesignSession::builder().config(cfg).build().unwrap();
    let rep = Table2Plan.reduce(&session, &[]).unwrap();

    let md = rep.render(Emit::Md);
    let json = rep.render(Emit::Json);
    let csv = rep.render(Emit::Csv);
    // structure first: holds even when blessing
    assert!(md.contains("## Table II: BNN architectures"), "{md}");
    assert!(md.contains("vgg3"), "{md}");
    assert!(!md.contains("vgg3_tiny"), "test twin excluded: {md}");
    let j = Json::parse(&json).unwrap();
    assert_eq!(j.req("plan").as_str(), "table2");
    assert!(csv.starts_with("# plan: table2\n"), "{csv}");

    check_golden("table2.md", &md);
    check_golden("table2.json", &json);
    check_golden("table2.csv", &csv);
    let _ = std::fs::remove_dir_all(&dir);
}

fn fig8_session(dir: &str) -> DesignSession {
    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    cfg.mc_samples = 50;
    cfg.eval_limit = 8;
    cfg.hist_limit = 8;
    cfg.n_seeds = 1;
    cfg.ks = vec![16, 14];
    cfg.point_cache = false;
    cfg.run_dir = dir.to_string();
    let session = DesignSession::builder().config(cfg).build().unwrap();
    inject_fmacs(&session, Dataset::FashionSyn);
    session
}

fn fig8_render(dir: &str) -> (String, String, String) {
    let session = fig8_session(dir);
    let plan = Fig8Plan {
        datasets: vec![Dataset::FashionSyn],
    };
    let specs = plan.specs(session.config());
    let points = session.query_many(&specs).unwrap();
    let rep = plan.reduce(&session, &points).unwrap();
    (
        rep.render(Emit::Md),
        rep.render(Emit::Json),
        rep.render(Emit::Csv),
    )
}

#[test]
fn fig8_report_matches_golden() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let dir_a = tmp_dir("golden_fig8a");
    let _ = std::fs::remove_dir_all(&dir_a);
    let (md, json, csv) = fig8_render(&dir_a);

    // structure first
    assert!(md.contains("### fashion_syn"), "{md}");
    assert!(md.contains("CapMin-V +var"), "{md}");
    let j = Json::parse(&json).unwrap();
    assert_eq!(j.req("plan").as_str(), "fig8");
    assert!(csv.contains("# series: fig8_fashion_syn"), "{csv}");

    // reproducibility: a second fresh session renders the same bytes
    // (this is what makes a byte-level golden meaningful at all)
    let dir_b = tmp_dir("golden_fig8b");
    let _ = std::fs::remove_dir_all(&dir_b);
    let (md2, json2, csv2) = fig8_render(&dir_b);
    assert_eq!(md, md2, "fig8 markdown must be deterministic");
    assert_eq!(json, json2);
    assert_eq!(csv, csv2);

    check_golden("fig8.md", &md);
    check_golden("fig8.json", &json);
    check_golden("fig8.csv", &csv);
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}
