//! Shared scaffolding for the offline integration tests (not a test
//! target itself — `tests/*/` directories are skipped by cargo).

#![allow(dead_code)] // each test crate uses a subset

use capmin::capmin::Fmac;
use capmin::data::synth::Dataset;
use capmin::session::DesignSession;

/// Every kernel tier the running CPU can execute — always scalar,
/// plus each supported SIMD tier (on an AVX-512 machine that is
/// avx512 *and* avx2; bit-equality sweeps run every entry).
pub fn kernel_tiers() -> Vec<capmin::backend::kernels::KernelKind> {
    use capmin::backend::kernels::KernelKind;
    KernelKind::TIERS
        .iter()
        .copied()
        .filter(|t| t.supported())
        .collect()
}

/// Skip guard: on an `xla` build with real artifacts present, the
/// session's `folded()` would train through the pipeline (slow, and
/// covered by tests/integration.rs) — the offline tests exercise the
/// no-XLA path only.
pub fn artifacts_present() -> bool {
    cfg!(feature = "xla")
        && capmin::runtime::artifacts_dir()
            .join("manifest.json")
            .exists()
}

/// The standard synthetic F_MAC fixture: a narrow first-matmul
/// histogram (grayscale conv, peak 5) and wide later ones (peak 16).
pub fn synthetic_fmacs(n_matmuls: usize) -> (Vec<Fmac>, Fmac) {
    let mut per = vec![];
    let mut sum = Fmac::new();
    for m in 0..n_matmuls {
        let f = Fmac::gaussian(if m == 0 { 5 } else { 16 }, 2.0, 1e8);
        sum.merge(&f);
        per.push(f);
    }
    (per, sum)
}

/// Inject the fixture for `ds` with the matmul count of its real
/// model, so evaluated queries (error model per matmul) line up.
pub fn inject_fmacs(session: &DesignSession, ds: Dataset) {
    let n_mat = capmin::backend::arch::model_meta(ds.spec().model)
        .unwrap()
        .n_matmuls();
    let (per, sum) = synthetic_fmacs(n_mat);
    session.put_fmac(ds, per, sum);
}

/// Per-process temp dir for a test tag.
pub fn tmp_dir(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("capmin_{tag}_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}
