//! Loopback integration tests for `capmin serve` (DESIGN.md §12):
//! spawn a real server on port 0, drive it with real TCP clients, and
//! pin the subsystem's three contracts — micro-batched `Infer`
//! replies are bit-identical to solo replies, worker/pool threads are
//! spawned once and stay stable across requests, and `Shutdown`
//! drains in-flight requests before the process lets go.
//!
//! Everything runs on the native backend's untrained fallback at
//! smoke scale — no artifacts, no training, just like the other
//! offline suites.

use std::net::SocketAddr;
use std::time::Duration;

use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::serve::{client::Client, server, ServeOptions};
use capmin::util::json::Json;

mod common;
use common::{artifacts_present, tmp_dir};

const DS: &str = "fashion_syn";
const K: usize = 14;
const SIGMA: f64 = 0.02;

fn serve_cfg(tag: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    cfg.threads = 2;
    cfg.mc_samples = 100;
    cfg.hist_limit = 32;
    cfg.eval_limit = 16;
    cfg.run_dir = tmp_dir(&format!("serve_{tag}"));
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    cfg
}

fn spawn_server(
    tag: &str,
    max_batch: usize,
    max_wait_ms: u64,
) -> (server::Server, SocketAddr, String) {
    let cfg = serve_cfg(tag);
    let run_dir = cfg.run_dir.clone();
    let addr: SocketAddr = "127.0.0.1:0".parse().unwrap();
    let mut opts = ServeOptions::new(addr);
    opts.max_batch = max_batch;
    opts.max_wait_ms = max_wait_ms;
    let srv = server::spawn(cfg, opts).unwrap();
    let addr = srv.addr();
    (srv, addr, run_dir)
}

/// A deterministic +-1 sample batch for `fashion_syn`.
fn samples(seed: u64, n: usize) -> Vec<Vec<f32>> {
    let px = Dataset::FashionSyn.spec().pixels();
    let mut rng = capmin::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| (0..px).map(|_| rng.pm1(0.5)).collect())
        .collect()
}

#[test]
fn concurrent_clients_mix_point_infer_stats() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let (srv, addr, run_dir) = spawn_server("mix", 4, 20);
    // warm the operating point + model once, and take the solo
    // baseline every concurrent infer must match bit-for-bit
    let mut warm = Client::connect(addr).unwrap();
    let xs = samples(11, 2);
    let baseline = warm
        .infer_logits(DS, K, SIGMA, 0, 7, &xs)
        .unwrap();
    let stats_before = warm.stats().unwrap();

    std::thread::scope(|s| {
        for ci in 0..6 {
            let xs = xs.clone();
            let baseline = baseline.clone();
            s.spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                // every client mixes all three request kinds
                let p = c.point(DS, K, SIGMA, 0, false).unwrap();
                assert!(p.req("c").as_f64() > 0.0, "client {ci}");
                assert_eq!(p.req("dataset").as_str(), DS);
                let logits =
                    c.infer_logits(DS, K, SIGMA, 0, 7, &xs).unwrap();
                assert_eq!(
                    logits, baseline,
                    "client {ci}: batched infer changed the reply"
                );
                let st = c.stats().unwrap();
                assert!(
                    st.req("stats").req("uptime_s").as_f64() >= 0.0
                );
            });
        }
    });

    let stats_after = warm.stats().unwrap();
    // worker/pool threads are spawned once: every figure the server
    // reports about its crews is identical before and after the storm
    let crew = |j: &Json| -> (f64, f64, f64) {
        let srv = j.req("stats").req("server");
        (
            srv.req("workers").as_f64(),
            srv.req("session_pool_workers").as_f64(),
            srv.req("infer_pool_workers").as_f64(),
        )
    };
    assert_eq!(crew(&stats_before), crew(&stats_after));
    // cfg.threads = 2 -> both persistent crews hold exactly 2 workers
    assert_eq!(crew(&stats_after).1, 2.0);
    assert_eq!(crew(&stats_after).2, 2.0);
    let reqs = stats_after.req("stats").req("requests");
    assert_eq!(reqs.req("point").as_f64(), 6.0);
    assert_eq!(reqs.req("infer").as_f64(), 7.0); // warm + 6 clients
    assert_eq!(stats_after.req("stats").req("errors").as_f64(), 0.0);

    warm.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn batched_infer_is_bit_identical_to_solo_and_coalesces() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    // a generous wait window so concurrently-fired requests are
    // certain to share a micro-batch
    let (srv, addr, run_dir) = spawn_server("batch", 8, 800);
    let mut warm = Client::connect(addr).unwrap();
    let xs = samples(21, 1);
    let baseline =
        warm.infer_logits(DS, K, SIGMA, 0, 3, &xs).unwrap();

    std::thread::scope(|s| {
        for ci in 0..6 {
            let xs = xs.clone();
            let baseline = baseline.clone();
            s.spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let got =
                    c.infer_logits(DS, K, SIGMA, 0, 3, &xs).unwrap();
                assert_eq!(got, baseline, "client {ci}");
            });
        }
    });

    let st = warm.stats().unwrap();
    let infer = st.req("stats").req("infer");
    assert_eq!(infer.req("samples").as_f64(), 7.0);
    assert!(
        infer.req("max_batch_requests").as_f64() >= 2.0,
        "six concurrent requests inside an 800 ms window never \
         coalesced: {}",
        st.to_string()
    );
    assert!(infer.req("batched_requests").as_f64() >= 2.0);

    warm.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn shutdown_drains_in_flight_requests() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    // long batch window: the in-flight infer is parked in the batcher
    // when the shutdown lands, and must still be answered
    let (srv, addr, run_dir) = spawn_server("drain", 4, 700);
    let mut warm = Client::connect(addr).unwrap();
    let xs = samples(31, 1);
    let baseline =
        warm.infer_logits(DS, K, SIGMA, 0, 9, &xs).unwrap();

    let in_flight = {
        let xs = xs.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            c.infer_logits(DS, K, SIGMA, 0, 9, &xs)
        })
    };
    // let the in-flight request reach the batcher, then pull the plug
    std::thread::sleep(Duration::from_millis(200));
    warm.shutdown().unwrap();

    let got = in_flight.join().unwrap().expect(
        "in-flight infer must be answered through the drain",
    );
    assert_eq!(got, baseline);
    srv.join().unwrap();
    // the port is actually released
    assert!(
        Client::connect(addr).is_err(),
        "server still accepting after drain"
    );
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn point_replies_carry_the_cost_vector() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let (srv, addr, run_dir) = spawn_server("cost", 2, 5);
    let mut c = Client::connect(addr).unwrap();
    let p = c.point(DS, K, SIGMA, 0, false).unwrap();
    // the cost vector is an additive reply field (DESIGN.md §13):
    // pre-cost clients keep parsing, new clients get the full price
    let cost = p.req("cost");
    assert!(cost.req("energy").as_f64() > 0.0);
    assert!(cost.req("area").as_f64() > 0.0);
    assert!(cost.req("latency").as_f64() > 0.0);
    assert!(cost.req("spike_times").as_f64() >= 1.0);
    assert_eq!(cost.req("c").as_f64(), p.req("c").as_f64());
    // Monte-Carlo provenance rides along the same way (DESIGN.md §15)
    let mc = p.req("mc");
    assert_eq!(mc.req("mode").as_str(), "paper");
    assert!(mc.req("draws").as_f64() > 0.0, "sigma > 0 solve drew");

    // consistent with a direct DesignSession query at the same knobs
    let cfg = serve_cfg("cost_direct");
    let direct_dir = cfg.run_dir.clone();
    let session = capmin::session::DesignSession::builder()
        .config(cfg)
        .build()
        .unwrap();
    let spec = capmin::session::OperatingPointSpec::new(
        Dataset::FashionSyn,
        K,
        SIGMA,
        0,
    );
    let direct = session.query(&spec).unwrap();
    assert_eq!(cost.req("energy").as_f64(), direct.cost.energy);
    assert_eq!(cost.req("area").as_f64(), direct.cost.area);
    assert_eq!(cost.req("latency").as_f64(), direct.cost.latency);
    assert_eq!(
        cost.req("spike_times").as_f64() as usize,
        direct.cost.spike_times
    );

    c.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
    let _ = std::fs::remove_dir_all(&direct_dir);
}

#[test]
fn protocol_errors_are_structured_and_survivable() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let (srv, addr, run_dir) = spawn_server("proto", 2, 5);
    let mut c = Client::connect(addr).unwrap();

    let bad = c.send_raw("this is not json").unwrap();
    assert!(!bad.req("ok").as_bool());
    assert!(bad.req("error").as_str().contains("bad JSON"));

    let vbad = c
        .send_raw(r#"{"v":99,"id":5,"type":"stats"}"#)
        .unwrap();
    assert!(!vbad.req("ok").as_bool());
    assert_eq!(vbad.req("id").as_f64(), 5.0);
    assert!(vbad.req("error").as_str().contains("unsupported"));

    let kbad = c
        .send_raw(
            concat!(
                r#"{"v":1,"id":6,"type":"point","#,
                r#""dataset":"fashion_syn","k":99}"#
            ),
        )
        .unwrap();
    assert!(!kbad.req("ok").as_bool());
    assert!(kbad.req("error").as_str().contains("1..=32"));

    // the connection survives all of that
    let st = c.stats().unwrap();
    assert!(st.req("ok").as_bool());
    assert_eq!(st.req("stats").req("errors").as_f64(), 3.0);

    c.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}
