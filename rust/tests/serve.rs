//! Loopback integration tests for `capmin serve` (DESIGN.md §12/§16):
//! spawn a real server on port 0, drive it with real TCP clients, and
//! pin the subsystem's contracts — micro-batched `Infer` replies are
//! bit-identical to solo replies, worker/pool/reactor threads are
//! spawned once and stay stable across requests, `Shutdown` drains
//! in-flight requests before the process lets go, replies keep
//! per-connection request order under pipelining, overload sheds with
//! structured `overloaded` replies instead of queueing unboundedly,
//! hostile inputs (oversized lines, slowloris stalls, abrupt
//! disconnects) are contained per connection, and a two-shard ring's
//! peer-fetched points are bit-identical to local solves.
//!
//! Everything runs on the native backend's untrained fallback at
//! smoke scale — no artifacts, no training, just like the other
//! offline suites.

use std::io::{BufRead, BufReader, Read as _, Write as _};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::serve::{
    client::Client, server, Backoff, HashRing, ServeOptions,
};
use capmin::session::OperatingPointSpec;
use capmin::util::json::Json;

mod common;
use common::{artifacts_present, tmp_dir};

const DS: &str = "fashion_syn";
const K: usize = 14;
const SIGMA: f64 = 0.02;

fn serve_cfg(tag: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    cfg.threads = 2;
    cfg.mc_samples = 100;
    cfg.hist_limit = 32;
    cfg.eval_limit = 16;
    cfg.run_dir = tmp_dir(&format!("serve_{tag}"));
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    cfg
}

fn spawn_server(
    tag: &str,
    max_batch: usize,
    max_wait_ms: u64,
) -> (server::Server, SocketAddr, String) {
    spawn_with(tag, |o| {
        o.max_batch = max_batch;
        o.max_wait_ms = max_wait_ms;
    })
}

/// [`spawn_server`] with full control over the serve options (the
/// robustness tests shrink `max_line`, `queue_cap`, `idle_timeout_ms`
/// far below production defaults to hit their limits fast).
fn spawn_with(
    tag: &str,
    tweak: impl FnOnce(&mut ServeOptions),
) -> (server::Server, SocketAddr, String) {
    let cfg = serve_cfg(tag);
    let run_dir = cfg.run_dir.clone();
    let addr: SocketAddr = "127.0.0.1:0".parse().unwrap();
    let mut opts = ServeOptions::new(addr);
    tweak(&mut opts);
    let srv = server::spawn(cfg, opts).unwrap();
    let addr = srv.addr();
    (srv, addr, run_dir)
}

/// A deterministic +-1 sample batch for `fashion_syn`.
fn samples(seed: u64, n: usize) -> Vec<Vec<f32>> {
    let px = Dataset::FashionSyn.spec().pixels();
    let mut rng = capmin::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| (0..px).map(|_| rng.pm1(0.5)).collect())
        .collect()
}

#[test]
fn concurrent_clients_mix_point_infer_stats() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let (srv, addr, run_dir) = spawn_server("mix", 4, 20);
    // warm the operating point + model once, and take the solo
    // baseline every concurrent infer must match bit-for-bit
    let mut warm = Client::connect(addr).unwrap();
    let xs = samples(11, 2);
    let baseline = warm
        .infer_logits(DS, K, SIGMA, 0, 7, &xs)
        .unwrap();
    let stats_before = warm.stats().unwrap();

    std::thread::scope(|s| {
        for ci in 0..6 {
            let xs = xs.clone();
            let baseline = baseline.clone();
            s.spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                // every client mixes all three request kinds
                let p = c.point(DS, K, SIGMA, 0, false).unwrap();
                assert!(p.req("c").as_f64() > 0.0, "client {ci}");
                assert_eq!(p.req("dataset").as_str(), DS);
                let logits =
                    c.infer_logits(DS, K, SIGMA, 0, 7, &xs).unwrap();
                assert_eq!(
                    logits, baseline,
                    "client {ci}: batched infer changed the reply"
                );
                let st = c.stats().unwrap();
                assert!(
                    st.req("stats").req("uptime_s").as_f64() >= 0.0
                );
            });
        }
    });

    let stats_after = warm.stats().unwrap();
    // worker/pool threads are spawned once: every figure the server
    // reports about its crews is identical before and after the storm
    let crew = |j: &Json| -> (f64, f64, f64) {
        let srv = j.req("stats").req("server");
        (
            srv.req("workers").as_f64(),
            srv.req("session_pool_workers").as_f64(),
            srv.req("infer_pool_workers").as_f64(),
        )
    };
    assert_eq!(crew(&stats_before), crew(&stats_after));
    // cfg.threads = 2 -> both persistent crews hold exactly 2 workers
    assert_eq!(crew(&stats_after).1, 2.0);
    assert_eq!(crew(&stats_after).2, 2.0);
    let reqs = stats_after.req("stats").req("requests");
    assert_eq!(reqs.req("point").as_f64(), 6.0);
    assert_eq!(reqs.req("infer").as_f64(), 7.0); // warm + 6 clients
    assert_eq!(stats_after.req("stats").req("errors").as_f64(), 0.0);

    warm.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn batched_infer_is_bit_identical_to_solo_and_coalesces() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    // a generous wait window so concurrently-fired requests are
    // certain to share a micro-batch
    let (srv, addr, run_dir) = spawn_server("batch", 8, 800);
    let mut warm = Client::connect(addr).unwrap();
    let xs = samples(21, 1);
    let baseline =
        warm.infer_logits(DS, K, SIGMA, 0, 3, &xs).unwrap();

    std::thread::scope(|s| {
        for ci in 0..6 {
            let xs = xs.clone();
            let baseline = baseline.clone();
            s.spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let got =
                    c.infer_logits(DS, K, SIGMA, 0, 3, &xs).unwrap();
                assert_eq!(got, baseline, "client {ci}");
            });
        }
    });

    let st = warm.stats().unwrap();
    let infer = st.req("stats").req("infer");
    assert_eq!(infer.req("samples").as_f64(), 7.0);
    assert!(
        infer.req("max_batch_requests").as_f64() >= 2.0,
        "six concurrent requests inside an 800 ms window never \
         coalesced: {}",
        st.to_string()
    );
    assert!(infer.req("batched_requests").as_f64() >= 2.0);

    warm.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn shutdown_drains_in_flight_requests() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    // long batch window: the in-flight infer is parked in the batcher
    // when the shutdown lands, and must still be answered
    let (srv, addr, run_dir) = spawn_server("drain", 4, 700);
    let mut warm = Client::connect(addr).unwrap();
    let xs = samples(31, 1);
    let baseline =
        warm.infer_logits(DS, K, SIGMA, 0, 9, &xs).unwrap();

    let in_flight = {
        let xs = xs.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            c.infer_logits(DS, K, SIGMA, 0, 9, &xs)
        })
    };
    // let the in-flight request reach the batcher, then pull the plug
    std::thread::sleep(Duration::from_millis(200));
    warm.shutdown().unwrap();

    let got = in_flight.join().unwrap().expect(
        "in-flight infer must be answered through the drain",
    );
    assert_eq!(got, baseline);
    srv.join().unwrap();
    // the port is actually released
    assert!(
        Client::connect(addr).is_err(),
        "server still accepting after drain"
    );
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn point_replies_carry_the_cost_vector() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let (srv, addr, run_dir) = spawn_server("cost", 2, 5);
    let mut c = Client::connect(addr).unwrap();
    let p = c.point(DS, K, SIGMA, 0, false).unwrap();
    // the cost vector is an additive reply field (DESIGN.md §13):
    // pre-cost clients keep parsing, new clients get the full price
    let cost = p.req("cost");
    assert!(cost.req("energy").as_f64() > 0.0);
    assert!(cost.req("area").as_f64() > 0.0);
    assert!(cost.req("latency").as_f64() > 0.0);
    assert!(cost.req("spike_times").as_f64() >= 1.0);
    assert_eq!(cost.req("c").as_f64(), p.req("c").as_f64());
    // Monte-Carlo provenance rides along the same way (DESIGN.md §15)
    let mc = p.req("mc");
    assert_eq!(mc.req("mode").as_str(), "paper");
    assert!(mc.req("draws").as_f64() > 0.0, "sigma > 0 solve drew");

    // consistent with a direct DesignSession query at the same knobs
    let cfg = serve_cfg("cost_direct");
    let direct_dir = cfg.run_dir.clone();
    let session = capmin::session::DesignSession::builder()
        .config(cfg)
        .build()
        .unwrap();
    let spec = capmin::session::OperatingPointSpec::new(
        Dataset::FashionSyn,
        K,
        SIGMA,
        0,
    );
    let direct = session.query(&spec).unwrap();
    assert_eq!(cost.req("energy").as_f64(), direct.cost.energy);
    assert_eq!(cost.req("area").as_f64(), direct.cost.area);
    assert_eq!(cost.req("latency").as_f64(), direct.cost.latency);
    assert_eq!(
        cost.req("spike_times").as_f64() as usize,
        direct.cost.spike_times
    );

    c.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
    let _ = std::fs::remove_dir_all(&direct_dir);
}

#[test]
fn protocol_errors_are_structured_and_survivable() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let (srv, addr, run_dir) = spawn_server("proto", 2, 5);
    let mut c = Client::connect(addr).unwrap();

    let bad = c.send_raw("this is not json").unwrap();
    assert!(!bad.req("ok").as_bool());
    assert!(bad.req("error").as_str().contains("bad JSON"));

    let vbad = c
        .send_raw(r#"{"v":99,"id":5,"type":"stats"}"#)
        .unwrap();
    assert!(!vbad.req("ok").as_bool());
    assert_eq!(vbad.req("id").as_f64(), 5.0);
    assert!(vbad.req("error").as_str().contains("unsupported"));

    let kbad = c
        .send_raw(
            concat!(
                r#"{"v":1,"id":6,"type":"point","#,
                r#""dataset":"fashion_syn","k":99}"#
            ),
        )
        .unwrap();
    assert!(!kbad.req("ok").as_bool());
    assert!(kbad.req("error").as_str().contains("1..=32"));

    // the connection survives all of that
    let st = c.stats().unwrap();
    assert!(st.req("ok").as_bool());
    assert_eq!(st.req("stats").req("errors").as_f64(), 3.0);

    c.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn oversized_request_line_is_refused_structurally() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let (srv, addr, run_dir) = spawn_with("oversize", |o| {
        o.max_line = 4096;
    });
    // 64 KiB with no newline: far past the cap. The server must
    // answer with a structured error bounded by one buffer — never
    // accumulate the line — then close.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&vec![b'x'; 64 * 1024]).unwrap();
    s.flush().unwrap();
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(!j.req("ok").as_bool());
    assert!(
        j.req("error").as_str().contains("exceeds"),
        "unexpected refusal: {line}"
    );
    line.clear();
    let n = r.read_line(&mut line).unwrap();
    assert_eq!(n, 0, "connection must close after the refusal");
    // one hostile connection, one error — the server is otherwise fine
    let mut c = Client::connect(addr).unwrap();
    let st = c.stats().unwrap();
    assert!(st.req("stats").req("errors").as_f64() >= 1.0);
    c.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn slowloris_stall_is_reaped_but_idle_connections_survive() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let (srv, addr, run_dir) = spawn_with("slowloris", |o| {
        o.idle_timeout_ms = 300;
    });
    // a fully idle connection opened before the attack: zero bytes
    let idle = TcpStream::connect(addr).unwrap();
    // the attacker: a partial request line, then a byte-trickle — the
    // stall clock runs from the partial line's START, so trickling
    // must not keep the connection alive
    let mut attacker = TcpStream::connect(addr).unwrap();
    attacker.write_all(b"{\"v\":1,").unwrap();
    for _ in 0..3 {
        std::thread::sleep(Duration::from_millis(100));
        let _ = attacker.write_all(b" "); // may race the close
    }
    attacker
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut buf = [0u8; 16];
    match attacker.read(&mut buf) {
        Ok(n) => assert_eq!(n, 0, "stalled conn must close, got data"),
        Err(_) => {} // reset also proves the close
    }
    // the idle connection was never reaped: it still serves, and the
    // reap above is visible in the metrics
    let mut w = idle.try_clone().unwrap();
    w.write_all(b"{\"v\":1,\"id\":9,\"type\":\"stats\"}\n").unwrap();
    let mut r = BufReader::new(idle);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let st = Json::parse(&line).unwrap();
    assert!(st.req("ok").as_bool(), "idle connection was reaped");
    assert!(
        st.req("stats")
            .req("serving")
            .req("idle_timeouts")
            .as_f64()
            >= 1.0
    );
    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn abrupt_disconnect_mid_flight_never_panics_or_leaks() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    // a long batch window parks the admitted infer; the client
    // vanishes before its reply exists
    let (srv, addr, run_dir) = spawn_server("abrupt", 8, 400);
    let mut warm = Client::connect(addr).unwrap();
    let xs = samples(41, 1);
    let baseline =
        warm.infer_logits(DS, K, SIGMA, 0, 5, &xs).unwrap();

    let row = xs[0]
        .iter()
        .map(|v| format!("{v}"))
        .collect::<Vec<_>>()
        .join(",");
    let line = format!(
        "{{\"v\":1,\"id\":1,\"type\":\"infer\",\
         \"dataset\":\"fashion_syn\",\"k\":14,\"sigma\":0.02,\
         \"seed\":5,\"x\":[[{row}]]}}\n"
    );
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(line.as_bytes()).unwrap();
    // admitted and parked in the batcher's 400 ms wait window...
    std::thread::sleep(Duration::from_millis(120));
    drop(s); // ...and gone. The completion fires into a dead slot.
    std::thread::sleep(Duration::from_millis(600));

    // no panic, no leaked pending slot, and the same request still
    // answers bit-identically for a live client
    let got = warm.infer_logits(DS, K, SIGMA, 0, 5, &xs).unwrap();
    assert_eq!(got, baseline);
    let st = warm.stats().unwrap();
    assert_eq!(
        st.req("stats")
            .req("serving")
            .req("queue_depth")
            .as_f64(),
        0.0,
        "dead client leaked a pending-queue slot"
    );
    warm.shutdown().unwrap();
    srv.join().unwrap(); // a panicked thread would surface here
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn overload_sheds_in_order_and_backoff_retries_through() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    // queue_cap 1: the first cold solve occupies the whole compute
    // queue, so pipelined followers must shed — never queue unboundedly
    let (srv, addr, run_dir) = spawn_with("overload", |o| {
        o.queue_cap = 1;
        o.max_batch = 1;
    });
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(
        b"{\"v\":1,\"id\":1,\"type\":\"point\",\
           \"dataset\":\"fashion_syn\",\"k\":14,\"sigma\":0.03}\n\
          {\"v\":1,\"id\":2,\"type\":\"point\",\
           \"dataset\":\"fashion_syn\",\"k\":15,\"sigma\":0.03}\n\
          {\"v\":1,\"id\":3,\"type\":\"point\",\
           \"dataset\":\"fashion_syn\",\"k\":16,\"sigma\":0.03}\n\
          {\"v\":1,\"id\":4,\"type\":\"stats\"}\n",
    )
    .unwrap();
    let mut r = BufReader::new(s);
    let mut lines = Vec::new();
    for _ in 0..4 {
        let mut l = String::new();
        r.read_line(&mut l).unwrap();
        lines.push(Json::parse(&l).unwrap());
    }
    // replies arrive in request order even though the sheds finished
    // long before the admitted solve (the sequencer's contract)
    for (i, j) in lines.iter().enumerate() {
        assert_eq!(
            j.req("id").as_f64(),
            (i + 1) as f64,
            "replies out of order: {lines:?}"
        );
    }
    assert!(lines[0].req("ok").as_bool(), "admitted solve failed");
    for j in &lines[1..3] {
        assert!(!j.req("ok").as_bool());
        assert!(
            j.req("overloaded").as_bool(),
            "shed reply lacks the overloaded marker: {j:?}"
        );
        assert!(j.req("retry_after_ms").as_f64() > 0.0);
    }
    let serving = lines[3].req("stats").req("serving");
    assert!(
        serving.req("admission").req("rejected_queue").as_f64()
            >= 2.0
    );

    // typed client half: a shed surfaces as a detectable Overloaded
    // error, and Backoff::retry turns it into eventual success
    let mut busy = TcpStream::connect(addr).unwrap();
    busy.write_all(
        b"{\"v\":1,\"id\":7,\"type\":\"point\",\
           \"dataset\":\"fashion_syn\",\"k\":17,\"sigma\":0.03}\n",
    )
    .unwrap();
    let mut c = Client::connect(addr).unwrap();
    let err = c
        .point(DS, 18, 0.03, 0, false)
        .expect_err("queue was occupied; this must shed");
    assert!(capmin::serve::client::retriable(&err));
    let shed = err
        .downcast_ref::<capmin::serve::Overloaded>()
        .expect("shed must downcast to the typed Overloaded error");
    assert!(shed.retry_after_ms > 0);
    let p = Backoff {
        attempts: 16,
        base_ms: 20,
        cap_ms: 600,
    }
    .retry(1, || c.point(DS, 18, 0.03, 0, false))
    .expect("backoff must ride out the transient overload");
    assert!(p.req("c").as_f64() > 0.0);
    // drain the busy solve's reply so the shutdown sees a quiet server
    let mut br = BufReader::new(busy);
    let mut l = String::new();
    br.read_line(&mut l).unwrap();
    assert!(Json::parse(&l).unwrap().req("ok").as_bool());

    c.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// A pipelined client that half-closes its write side after sending
/// (shutdown(SHUT_WR)) is owed every reply: EOF must drain the
/// connection — buffered requests answered, in order — not kill it.
#[test]
fn half_closed_pipeline_still_gets_all_replies() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let (srv, addr, run_dir) = spawn_server("halfclose", 4, 10);
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(
        b"{\"v\":1,\"id\":1,\"type\":\"point\",\
           \"dataset\":\"fashion_syn\",\"k\":14,\"sigma\":0.02}\n\
          {\"v\":1,\"id\":2,\"type\":\"point\",\
           \"dataset\":\"fashion_syn\",\"k\":14,\"sigma\":0.02}\n\
          {\"v\":1,\"id\":3,\"type\":\"stats\"}\n",
    )
    .unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    let mut r = BufReader::new(s);
    for want in 1..=3 {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap_or_else(|e| {
            panic!("reply {want} unparsable ({e}): {line:?}")
        });
        assert!(
            j.req("ok").as_bool(),
            "reply {want} failed: {line:?}"
        );
        assert_eq!(j.req("id").as_f64(), want as f64);
    }
    // everything owed was delivered; now the server closes its side
    let mut rest = String::new();
    assert_eq!(r.read_line(&mut rest).unwrap(), 0);

    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// A ring peer that accepts connections but never replies (wedged,
/// not down) must cost at most the peer timeout before the requester
/// falls back to a local solve — never a blocked session thread.
#[test]
fn wedged_peer_times_out_and_falls_back_to_local_solve() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let cfg = serve_cfg("wedged_peer");
    let run_dir = cfg.run_dir.clone();
    // the wedge: a bound listener whose backlog completes TCP
    // handshakes, but nothing ever accepts or answers
    let wedge = TcpListener::bind("127.0.0.1:0").unwrap();
    let wedge_addr = wedge.local_addr().unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut opts = ServeOptions::new(addr);
    opts.peers = vec![addr, wedge_addr];
    opts.shard = 0;
    opts.peer_timeout_ms = 150;
    // a spec the wedged shard 1 owns, so shard 0 must try the fetch
    let ring = HashRing::new(2);
    let probe = cfg.clone();
    let srv = server::spawn_on(listener, cfg, opts).unwrap();
    let (k1, sigma1) = (1..=32usize)
        .flat_map(|k| {
            [0.0, 0.01, 0.02, 0.03, 0.05]
                .into_iter()
                .map(move |s| (k, s))
        })
        .find(|&(k, s)| {
            let spec = OperatingPointSpec::new(
                Dataset::FashionSyn,
                k,
                s,
                0,
            );
            ring.owner(&spec.cache_key(&probe)) == 1
        })
        .expect("some (k, sigma) must hash to shard 1");

    let mut c = Client::connect(addr).unwrap();
    let t0 = Instant::now();
    let p = c.point(DS, k1, sigma1, 0, false).unwrap();
    assert!(p.req("c").as_f64() > 0.0, "local fallback failed");
    // the fetch is bounded by the 150 ms timeout (no retry doubles a
    // timeout), plus the local cold solve — nowhere near a deadlock
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "peer fetch not bounded: {:?}",
        t0.elapsed()
    );
    let st = c.stats().unwrap();
    let peer = st.req("stats").req("serving").req("peer");
    assert!(
        peer.req("misses").as_f64() >= 1.0,
        "the wedged peer was never tried: {}",
        st.to_string()
    );
    assert_eq!(peer.req("hits").as_f64(), 0.0);

    c.shutdown().unwrap();
    srv.join().unwrap();
    drop(wedge);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn two_shard_peer_fetch_is_bit_identical_to_a_local_solve() {
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let cfg0 = serve_cfg("ring0");
    let cfg1 = serve_cfg("ring1");
    let dirs = [cfg0.run_dir.clone(), cfg1.run_dir.clone()];
    // find a spec shard 1 owns, with the exact key the servers use
    let ring = HashRing::new(2);
    let probe = cfg0.clone();
    let (k1, sigma1) = (1..=32usize)
        .flat_map(|k| {
            [0.0, 0.01, 0.02, 0.03, 0.05]
                .into_iter()
                .map(move |s| (k, s))
        })
        .find(|&(k, s)| {
            let spec = OperatingPointSpec::new(
                Dataset::FashionSyn,
                k,
                s,
                0,
            );
            ring.owner(&spec.cache_key(&probe)) == 1
        })
        .expect("some (k, sigma) must hash to shard 1");

    // two in-process shards with DISTINCT run dirs: a peer fetch has
    // to really cross the wire, it cannot alias shard 0's caches
    let servers = server::spawn_ring(
        vec![cfg0, cfg1],
        ServeOptions::new("127.0.0.1:0".parse().unwrap()),
    )
    .unwrap();
    let addrs: Vec<SocketAddr> =
        servers.iter().map(|s| s.addr()).collect();

    // ask shard 0 for shard 1's point: answered via peer_point
    let mut c = Client::connect(addrs[0]).unwrap();
    let via_peer = c.point(DS, k1, sigma1, 0, false).unwrap();
    // again: served from the verified peer cache, same content
    let again = c.point(DS, k1, sigma1, 0, false).unwrap();
    let st = c.stats().unwrap();
    let peer = st.req("stats").req("serving").req("peer");
    assert!(
        peer.req("hits").as_f64() >= 1.0,
        "the owner never answered; requester fell back local: {}",
        st.to_string()
    );

    // the standalone truth at identical knobs, fresh run dir
    let (solo_srv, solo_addr, solo_dir) =
        spawn_server("ring_solo", 8, 2);
    let mut sc = Client::connect(solo_addr).unwrap();
    let solo = sc.point(DS, k1, sigma1, 0, false).unwrap();

    // bit-identical replies modulo the client-chosen request id and
    // the per-request trace id (every admission mints a fresh one —
    // DESIGN.md §17)
    let strip = |j: &Json| {
        let mut j = j.clone();
        if let Json::Obj(m) = &mut j {
            m.remove("id");
            m.remove("trace");
        }
        j
    };
    assert_eq!(
        strip(&via_peer),
        strip(&solo),
        "peer-fetched point differs from a local solve"
    );
    assert_eq!(strip(&again), strip(&solo));

    sc.shutdown().unwrap();
    solo_srv.join().unwrap();
    for addr in &addrs {
        Client::connect(*addr).unwrap().shutdown().unwrap();
    }
    for s in servers {
        s.join().unwrap();
    }
    for d in dirs.iter().chain([&solo_dir]) {
        let _ = std::fs::remove_dir_all(d);
    }
}
