//! Cross-layer integration tests: Rust substrates vs the AOT artifacts.
//!
//! The strongest signal in the repo: the Rust bit-packed engine, the jnp
//! oracle artifact and the Pallas-kernel artifact must agree
//! *bit-for-bit*, including in stochastic error-injection mode (shared
//! counter-based PRNG over logical indices). Requires `make artifacts`
//! and a build with the `xla` feature (the offline twin of this suite
//! is tests/backend.rs).

#![cfg(feature = "xla")]

use capmin::bnn::{BitMatrix, ErrorModel, SubMacEngine};
use capmin::coordinator::config::ExperimentConfig;
use capmin::coordinator::evaluator::stack_error_models;
use capmin::data::synth::Dataset;
use capmin::data::{Loader, Split};
use capmin::runtime::{
    artifacts_dir, lit_f32, lit_u32_scalar, to_f32, Runtime,
};
use capmin::session::{DesignSession, OperatingPointSpec};
use capmin::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping integration tests: run `make artifacts`");
        return None;
    }
    Some(Runtime::new().unwrap())
}

fn rand_pm(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.pm1(0.5)).collect()
}

fn random_error_model(rng: &mut Rng) -> ErrorModel {
    // random row-stochastic matrix with mass spread over +-2 diagonals
    let mut full = vec![vec![0.0f64; 33]; 33];
    for (m, row) in full.iter_mut().enumerate() {
        let mut weights = [0.0f64; 5];
        let mut sum = 0.0;
        for w in weights.iter_mut() {
            *w = rng.f64() + 0.05;
            sum += *w;
        }
        for (d, w) in (-2i64..=2).zip(weights.iter()) {
            let j = (m as i64 + d).clamp(0, 32) as usize;
            row[j] += w / sum;
        }
    }
    ErrorModel::from_full(&full)
}

/// Rust engine == Pallas kernel artifact, bit for bit, stochastic mode.
#[test]
fn rust_engine_matches_kernel_artifact_bit_exact() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("vgg3_tiny", "kernel").unwrap();
    let sig = &exe.sig;
    let (o, k) = (sig.inputs[0].shape[0], sig.inputs[0].shape[1]);
    let d = sig.inputs[1].shape[1];

    let mut rng = Rng::new(2024);
    let wv = rand_pm(&mut rng, o * k);
    let xv_colmajor = rand_pm(&mut rng, k * d); // [k, d] row-major
    let em = random_error_model(&mut rng);

    for seed in [0u32, 7, 0xDEAD_BEEF] {
        // artifact side
        let outs = exe
            .run(&[
                lit_f32(&[o, k], &wv).unwrap(),
                lit_f32(&[k, d], &xv_colmajor).unwrap(),
                lit_f32(&[33, 33], &em.cdf).unwrap(),
                lit_f32(&[33], &em.vals).unwrap(),
                lit_u32_scalar(seed),
            ])
            .unwrap();
        let artifact_out = to_f32(&outs[0]).unwrap();

        // rust side: engine wants X rows = D entries of length k
        let mut x_rows = vec![0.0f32; d * k];
        for ki in 0..k {
            for di in 0..d {
                x_rows[di * k + ki] = xv_colmajor[ki * d + di];
            }
        }
        // kernel artifact was lowered with beta = padded k and salt = 0
        let eng = SubMacEngine::new(o, k, &wv, k);
        let xb = BitMatrix::pack(d, k, &x_rows, false);
        let rust_out = eng.matmul_error(&xb, &em, seed, 0);

        assert_eq!(
            rust_out, artifact_out,
            "bit-exact mismatch at seed {seed}"
        );
    }
}

/// jnp-engine artifact == Pallas-engine artifact on a whole model
/// forward pass, stochastic mode (bit-exact by shared PRNG).
#[test]
fn eval_and_evalp_artifacts_bit_identical() {
    let Some(rt) = runtime() else { return };
    let mi = rt.manifest.model("vgg3_tiny").clone();
    let init = rt.load("vgg3_tiny", "init").unwrap();
    let export = rt.load("vgg3_tiny", "export").unwrap();
    let key = capmin::runtime::lit_u32(&[2], &[1, 2]).unwrap();
    let ps = init.run(&[key]).unwrap();
    let folded = export.run(&ps).unwrap();

    let mut rng = Rng::new(5);
    let eb = mi.eval_batch;
    let px: usize = mi.in_shape.iter().product();
    let x = rand_pm(&mut rng, eb * px);
    let ems: Vec<ErrorModel> = (0..mi.n_matmuls)
        .map(|_| random_error_model(&mut rng))
        .collect();
    let (cdf_v, vals_v) = stack_error_models(&ems);

    let x_shape = [&[eb], mi.in_shape.as_slice()].concat();
    let mut run = |kind: &str| -> Vec<f32> {
        let exe = rt.load("vgg3_tiny", kind).unwrap();
        let mut inputs: Vec<xla::Literal> =
            folded.iter().map(clone_lit).collect();
        inputs.push(lit_f32(&x_shape, &x).unwrap());
        inputs
            .push(lit_f32(&[mi.n_matmuls, 33, 33], &cdf_v).unwrap());
        inputs.push(lit_f32(&[mi.n_matmuls, 33], &vals_v).unwrap());
        inputs.push(lit_u32_scalar(99));
        to_f32(&exe.run(&inputs).unwrap()[0]).unwrap()
    };
    let a = run("eval");
    let b = run("evalp");
    assert_eq!(a, b, "jnp and Pallas engines must agree bit-for-bit");
    assert!(a.iter().all(|v| v.is_finite()));
}

fn clone_lit(l: &xla::Literal) -> xla::Literal {
    // Literal has no Clone; round-trip through host (test-only helper)
    let shape: Vec<usize> = l
        .array_shape()
        .unwrap()
        .dims()
        .iter()
        .map(|&d| d as usize)
        .collect();
    lit_f32(&shape, &to_f32(l).unwrap()).unwrap()
}

/// Identity error model through the eval artifact == ideal accuracy
/// computed by the hist artifact's clean logits, sample for sample.
#[test]
fn identity_error_model_matches_clean_forward() {
    let Some(rt) = runtime() else { return };
    let mi = rt.manifest.model("vgg3_tiny").clone();
    let init = rt.load("vgg3_tiny", "init").unwrap();
    let export = rt.load("vgg3_tiny", "export").unwrap();
    let key = capmin::runtime::lit_u32(&[2], &[3, 4]).unwrap();
    let ps = init.run(&[key]).unwrap();
    let folded = export.run(&ps).unwrap();

    let spec = Dataset::FashionSyn.spec();
    let mut loader =
        Loader::new(spec, Split::Test, mi.eval_batch, 64, 11);
    let batch = loader.next_batch();
    let x_shape = [&[mi.eval_batch], mi.in_shape.as_slice()].concat();
    let x = lit_f32(&x_shape, &batch.x).unwrap();

    // eval with identity per-matmul models
    let ems: Vec<ErrorModel> =
        (0..mi.n_matmuls).map(|_| ErrorModel::identity()).collect();
    let (cdf_v, vals_v) = stack_error_models(&ems);
    let eval = rt.load("vgg3_tiny", "eval").unwrap();
    let mut inputs: Vec<xla::Literal> =
        folded.iter().map(clone_lit).collect();
    inputs.push(x);
    inputs.push(lit_f32(&[mi.n_matmuls, 33, 33], &cdf_v).unwrap());
    inputs.push(lit_f32(&[mi.n_matmuls, 33], &vals_v).unwrap());
    inputs.push(lit_u32_scalar(0));
    let eval_logits = to_f32(&eval.run(&inputs).unwrap()[0]).unwrap();

    // hist artifact computes the exact (ungrouped) logits — but on the
    // hist batch size; reuse eval batch if equal, else skip comparison
    if mi.hist_batch == mi.eval_batch {
        let hist = rt.load("vgg3_tiny", "hist").unwrap();
        let mut hin: Vec<xla::Literal> =
            folded.iter().map(clone_lit).collect();
        hin.push(lit_f32(&x_shape, &batch.x).unwrap());
        let outs = hist.run(&hin).unwrap();
        let clean_logits = to_f32(&outs[1]).unwrap();
        for (a, b) in eval_logits.iter().zip(clean_logits.iter()) {
            assert!(
                (a - b).abs() <= 1e-3 * b.abs().max(1.0),
                "identity model must reproduce clean logits: {a} vs {b}"
            );
        }
    }
}

/// Full-pipeline smoke through the session API: train the tiny model,
/// fold, query hardware operating points on its F_MACs, and check the
/// accuracy ordering the paper's Fig. 8 rests on.
#[test]
fn session_smoke_orderings() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ExperimentConfig::default();
    cfg.train_steps = 40;
    cfg.train_limit = 256;
    cfg.eval_limit = 64;
    cfg.hist_limit = 64;
    cfg.mc_samples = 200;
    cfg.run_dir = std::env::temp_dir()
        .join(format!("capmin_it_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string();
    let run_dir = cfg.run_dir.clone();
    let _ = std::fs::remove_dir_all(&run_dir);
    // train vgg3_tiny through the Trainer directly (the dataset binds
    // to the full vgg3; the tiny twin keeps this test fast), then
    // inject its F_MACs into the session
    let trainer = capmin::coordinator::trainer::Trainer::new(&rt);
    let spec = Dataset::FashionSyn.spec();
    let mi = rt.manifest.model("vgg3_tiny").clone();
    let mut loader = Loader::new(
        spec.clone(),
        Split::Train,
        mi.train_batch,
        256,
        1,
    );
    let trained = trainer
        .train("vgg3_tiny", &mut loader, 40, 1e-2, 1000, 3, &mut |_, _| {})
        .unwrap();
    let folded = trainer.export(&trained).unwrap();

    let hist = capmin::coordinator::histogrammer::Histogrammer::new(&rt);
    let hres = hist
        .extract_dataset("vgg3_tiny", &folded, spec.clone(), 64, 9)
        .unwrap();
    assert!(hres.accuracy > 0.3, "tiny model should learn something");
    // histogram sanity: peak near mid levels for the big matmuls
    let total = hres.sum.total();
    assert!(total > 0);

    let session = DesignSession::builder()
        .config(cfg)
        .runtime(rt)
        .build()
        .unwrap();
    session.put_fmac(
        Dataset::FashionSyn,
        hres.per_matmul.clone(),
        hres.sum.clone(),
    );
    let ev = session.evaluator().unwrap();
    let hw32 = session
        .query(&OperatingPointSpec::new(Dataset::FashionSyn, 32, 0.0, 0))
        .unwrap();
    let a32 = ev
        .accuracy("vgg3_tiny", &folded, spec.clone(), &hw32.ems, 64, 1)
        .unwrap();
    let hw6 = session
        .query(&OperatingPointSpec::new(Dataset::FashionSyn, 6, 0.0, 0))
        .unwrap();
    let a6 = ev
        .accuracy("vgg3_tiny", &folded, spec.clone(), &hw6.ems, 64, 1)
        .unwrap();
    // k=32 is lossless: must match the clean accuracy of the same split
    assert!(a32 >= a6 - 1e-9, "more levels can't hurt: {a32} vs {a6}");
    // capacitor ordering
    assert!(hw6.c < hw32.c, "smaller k -> smaller capacitor");
    let _ = std::fs::remove_dir_all(&run_dir);
}
