//! `DesignSession` memoization and batch-query semantics, fully
//! offline: hardware-only queries (`eval: None`) on injected F_MAC
//! statistics never touch the PJRT runtime, so these run without
//! `make artifacts`.

use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::session::{DesignSession, OperatingPointSpec};

mod common;
use common::{artifacts_present, inject_fmacs, synthetic_fmacs};

fn session_in(tag: &str) -> (DesignSession, String) {
    let dir = std::env::temp_dir()
        .join(format!(
            "capmin_session_test_{tag}_{}",
            std::process::id()
        ))
        .to_str()
        .unwrap()
        .to_string();
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ExperimentConfig::default();
    cfg.mc_samples = 200;
    cfg.run_dir = dir.clone();
    let session = DesignSession::builder().config(cfg).build().unwrap();
    let (per, sum) = synthetic_fmacs(2);
    session.put_fmac(Dataset::FashionSyn, per, sum);
    (session, dir)
}

#[test]
fn repeat_query_hits_memory_with_no_second_solve() {
    let (session, dir) = session_in("memo");
    let spec =
        OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0);
    let a = session.query(&spec).unwrap();
    let s1 = session.stats();
    assert_eq!((s1.queries, s1.solves, s1.mem_hits), (1, 1, 0));

    let b = session.query(&spec).unwrap();
    let s2 = session.stats();
    assert_eq!(s2.queries, 2);
    assert_eq!(s2.solves, 1, "no second MC run for the same spec");
    assert_eq!(s2.mem_hits, 1);
    assert_eq!(*a, *b, "memoized point is identical");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fresh_session_replays_from_disk() {
    let (session, dir) = session_in("disk");
    let spec =
        OperatingPointSpec::new(Dataset::FashionSyn, 16, 0.02, 2);
    let a = session.query(&spec).unwrap();
    assert!(
        session
            .store()
            .path("points")
            .join(format!("{}.json", spec.cache_key(session.config())))
            .exists(),
        "point persisted under runs/points/"
    );

    // second session over the same run dir: no fmacs injected, no
    // runtime — the disk cache alone must answer
    let mut cfg = session.config().clone();
    cfg.run_dir = dir.clone();
    let replay = DesignSession::builder().config(cfg).build().unwrap();
    let b = replay.query(&spec).unwrap();
    let s = replay.stats();
    assert_eq!((s.disk_hits, s.solves), (1, 0));
    assert_eq!(*a, *b, "disk round-trip is exact");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pre_cost_point_files_replay_and_are_repriced() {
    // a pre-§13 cache file has no `cost` field; the loader must
    // reprice it from c + times instead of rejecting the file
    let (session, dir) = session_in("precost");
    let spec =
        OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 2);
    let a = session.query(&spec).unwrap();
    let path = session
        .store()
        .path("points")
        .join(format!("{}.json", spec.cache_key(session.config())));
    let text = std::fs::read_to_string(&path).unwrap();
    let at = text.find(",\"cost\":").expect("cost field persisted");
    let legacy = format!("{}}}", &text[..at]);
    assert_ne!(legacy, text);
    std::fs::write(&path, legacy).unwrap();

    let mut cfg = session.config().clone();
    cfg.run_dir = dir.clone();
    let replay = DesignSession::builder().config(cfg).build().unwrap();
    let b = replay.query(&spec).unwrap();
    let s = replay.stats();
    assert_eq!(
        (s.disk_hits, s.solves),
        (1, 0),
        "old cost-less file still answers from disk"
    );
    assert_eq!(b.cost, a.cost, "repriced on load");
    assert_eq!(*a, *b);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn query_many_matches_sequential_query_exactly() {
    let ks = [32usize, 24, 16, 14, 10, 6];
    let mk_specs = || -> Vec<OperatingPointSpec> {
        ks.iter()
            .flat_map(|&k| {
                [
                    OperatingPointSpec::new(
                        Dataset::FashionSyn,
                        k,
                        0.0,
                        0,
                    ),
                    OperatingPointSpec::new(
                        Dataset::FashionSyn,
                        k,
                        0.03,
                        0,
                    ),
                ]
            })
            .collect()
    };

    let (seq, dir_a) = session_in("seq");
    let sequential: Vec<_> = mk_specs()
        .iter()
        .map(|s| seq.query(s).unwrap())
        .collect();

    let (par, dir_b) = session_in("par");
    let batched = par.query_many(&mk_specs()).unwrap();

    assert_eq!(sequential.len(), batched.len());
    for (a, b) in sequential.iter().zip(batched.iter()) {
        assert_eq!(**a, **b, "thread scheduling must not change answers");
    }
    let s = par.stats();
    assert_eq!(s.queries, batched.len() as u64);
    assert_eq!(s.solves, batched.len() as u64);
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn query_many_dedupes_and_replays() {
    let (session, dir) = session_in("dedup");
    let spec =
        OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0);
    let points =
        session.query_many(&[spec, spec, spec]).unwrap();
    let s = session.stats();
    assert_eq!(s.queries, 3);
    assert_eq!(s.solves, 1, "duplicate specs share one solve");
    assert_eq!(
        s.deduped, 2,
        "the two batch duplicates are fanned out, not re-solved"
    );
    assert_eq!(*points[0], *points[1]);
    assert_eq!(*points[1], *points[2]);

    // a second batch is all memory hits (no further dedup needed)
    session.query_many(&[spec, spec]).unwrap();
    let s = session.stats();
    assert_eq!(s.solves, 1);
    assert_eq!(s.mem_hits, 2);
    assert_eq!(s.deduped, 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn query_many_dedupes_eval_variants_onto_one_solve() {
    // same hardware point under different eval settings: one MC solve,
    // distinct full-key entries (eval runs on the native untrained
    // fallback at smoke scale — accuracy values are irrelevant here).
    // Skip when an xla build could reach real artifacts: folded()
    // would train there (covered by tests/integration.rs).
    if artifacts_present() {
        eprintln!("skipping: artifacts present");
        return;
    }
    let dir = std::env::temp_dir()
        .join(format!(
            "capmin_session_test_evalvariants_{}",
            std::process::id()
        ))
        .to_str()
        .unwrap()
        .to_string();
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ExperimentConfig::default();
    cfg.backend = "native".into();
    cfg.mc_samples = 100;
    cfg.eval_limit = 8;
    cfg.run_dir = dir.clone();
    let session = DesignSession::builder().config(cfg).build().unwrap();
    inject_fmacs(&session, Dataset::FashionSyn);

    let hw = OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0);
    let batch = [hw, hw.with_eval(1, 1), hw.with_eval(100, 1)];
    let points = session.query_many(&batch).unwrap();
    let s = session.stats();
    assert_eq!(s.queries, 3);
    assert_eq!(s.solves, 1, "eval variants share the hardware solve");
    assert_eq!(s.deduped, 0, "distinct full keys are not duplicates");
    assert_eq!(s.evals, 2, "only the eval-carrying specs evaluate");
    assert_eq!(points[0].c, points[1].c);
    assert_eq!(points[1].c, points[2].c);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn point_cache_writes_are_atomic_under_concurrent_sessions() {
    // two sessions over the SAME run dir (a serving process next to a
    // CLI run) racing to persist the same spec: every interleaving
    // must leave a complete `<key>.json` and zero `*.tmp` litter —
    // the unique-tmp + rename discipline in PointCache::put
    let dir = std::env::temp_dir()
        .join(format!(
            "capmin_session_test_atomic_{}",
            std::process::id()
        ))
        .to_str()
        .unwrap()
        .to_string();
    let _ = std::fs::remove_dir_all(&dir);
    let spec = OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.02, 0);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let dir = dir.clone();
            s.spawn(move || {
                // sessions are built on their own threads (the facade
                // is single-threaded by design)
                let mut cfg = ExperimentConfig::default();
                cfg.mc_samples = 200;
                cfg.run_dir = dir;
                let session =
                    DesignSession::builder().config(cfg).build().unwrap();
                let (per, sum) = synthetic_fmacs(2);
                session.put_fmac(Dataset::FashionSyn, per, sum);
                session.query(&spec).unwrap();
            });
        }
    });
    // a fresh session must replay the racy key cleanly from disk
    let mut cfg = ExperimentConfig::default();
    cfg.mc_samples = 200;
    cfg.run_dir = dir.clone();
    let replay = DesignSession::builder().config(cfg).build().unwrap();
    replay.query(&spec).unwrap();
    assert_eq!(replay.stats().disk_hits, 1, "torn or missing file");
    let tmps: Vec<_> = std::fs::read_dir(replay.store().path("points"))
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            e.path().extension().map(|x| x == "tmp").unwrap_or(false)
        })
        .collect();
    assert!(tmps.is_empty(), "tmp litter: {tmps:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn distinct_specs_are_distinct_points() {
    let (session, dir) = session_in("distinct");
    let a = session
        .query(&OperatingPointSpec::new(Dataset::FashionSyn, 14, 0.0, 0))
        .unwrap();
    let b = session
        .query(&OperatingPointSpec::new(Dataset::FashionSyn, 10, 0.0, 0))
        .unwrap();
    assert!(b.c < a.c, "smaller k -> smaller capacitor");
    assert_eq!(session.stats().solves, 2);
    let _ = std::fs::remove_dir_all(&dir);
}
