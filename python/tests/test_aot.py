"""AOT artifacts: manifest integrity and HLO-text round-trip contract."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), '..', '..', 'artifacts')
MANIFEST = os.path.join(ART, 'manifest.json')

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST),
    reason='run `make artifacts` first')


def _manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_models_have_all_kinds():
    man = _manifest()
    kinds = {'init', 'train', 'export', 'hist', 'eval', 'evalp', 'kernel'}
    for name, m in man['models'].items():
        got = {a['kind'] for a in m['artifacts']}
        assert kinds <= got, (name, got)


def test_hlo_text_parseable_header():
    man = _manifest()
    for m in man['models'].values():
        for a in m['artifacts']:
            path = os.path.join(ART, a['path'])
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(200)
            assert head.startswith('HloModule'), path


def test_signatures_consistent():
    man = _manifest()
    for m in man['models'].values():
        by_kind = {a['kind']: a for a in m['artifacts']}
        # init outputs == train's leading params+state inputs
        init_out = by_kind['init']['outputs']
        train_in = by_kind['train']['inputs']
        n = m['n_params'] + m['n_state']
        assert [o['shape'] for o in init_out] == \
            [i['shape'] for i in train_in[:n]]
        # export outputs == eval's folded inputs
        exp_out = by_kind['export']['outputs']
        eval_in = by_kind['eval']['inputs']
        assert [o['shape'] for o in exp_out] == \
            [i['shape'] for i in eval_in[:m['n_folded']]]
        # eval and evalp share the full signature
        assert by_kind['eval']['inputs'] == by_kind['evalp']['inputs']
        # error-model inputs are runtime inputs (sweeps need no recompile)
        names = [i['name'] for i in eval_in]
        assert names[-3:] == ['cdf', 'vals', 'seed']


def test_datasets_reference_known_models():
    man = _manifest()
    for ds, d in man['datasets'].items():
        assert d['model'] in {'vgg3', 'vgg7', 'resnet18'}, ds
