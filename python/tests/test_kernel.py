"""Pallas sub-MAC kernel vs pure-jnp oracle — the core L1 signal.

The kernel and the oracle share the counter-based PRNG over logical
indices, so even the *stochastic* outputs must match bit-for-bit.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, submac

RNG = np.random.default_rng(42)


def rand_pm(shape):
    return jnp.asarray(RNG.choice([-1.0, 1.0], shape).astype(np.float32))


def rand_cdf(alpha=0.3):
    p = RNG.dirichlet(np.ones(ref.N_LEVELS) * alpha,
                      size=ref.N_LEVELS).astype(np.float32)
    cdf = np.cumsum(p, axis=1)
    cdf[:, -1] = 1.0
    return jnp.asarray(cdf)


SHAPES = [
    (8, 32, 16),     # single group
    (16, 64, 40),    # two groups, ragged D
    (48, 96, 200),   # ragged everything vs default blocks
    (33, 160, 129),  # prime-ish
    (4, 320, 8),     # many groups, few outputs
]


@pytest.mark.parametrize('o,k,d', SHAPES)
def test_exact_mode_matches_dot(o, k, d):
    wb, xb = rand_pm((o, k)), rand_pm((k, d))
    out = ref.submac_matmul_ref(wb, xb, ref.identity_cdf(),
                                ref.identity_vals(), jnp.uint32(1), 0)
    np.testing.assert_array_equal(np.array(out), np.array(wb @ xb))


@pytest.mark.parametrize('o,k,d', SHAPES)
def test_pallas_matches_ref_exact(o, k, d):
    wb, xb = rand_pm((o, k)), rand_pm((k, d))
    r = ref.submac_matmul_ref(wb, xb, ref.identity_cdf(),
                              ref.identity_vals(), jnp.uint32(1), 5)
    p = submac.submac_matmul_pallas(wb, xb, ref.identity_cdf(),
                                    ref.identity_vals(), jnp.uint32(1), 5)
    np.testing.assert_array_equal(np.array(r), np.array(p))


@pytest.mark.parametrize('o,k,d', SHAPES)
def test_pallas_matches_ref_stochastic(o, k, d):
    wb, xb = rand_pm((o, k)), rand_pm((k, d))
    cdf = rand_cdf()
    vals = ref.identity_vals()
    for seed in (0, 7, 12345):
        r = ref.submac_matmul_ref(wb, xb, cdf, vals, jnp.uint32(seed), 9)
        p = submac.submac_matmul_pallas(wb, xb, cdf, vals,
                                        jnp.uint32(seed), 9)
        np.testing.assert_array_equal(np.array(r), np.array(p))


@pytest.mark.parametrize('bo,bd', [(8, 32), (16, 64), (32, 128), (64, 256)])
def test_pallas_block_shape_invariance(bo, bd):
    """The PRNG uses logical indices, so blocking must not change results."""
    wb, xb = rand_pm((40, 64)), rand_pm((64, 100))
    cdf = rand_cdf()
    base = ref.submac_matmul_ref(wb, xb, cdf, ref.identity_vals(),
                                 jnp.uint32(3), 2)
    p = submac.submac_matmul_pallas(wb, xb, cdf, ref.identity_vals(),
                                    jnp.uint32(3), 2,
                                    block_o=bo, block_d=bd)
    np.testing.assert_array_equal(np.array(base), np.array(p))


def test_clip_cdf_equals_eq4():
    """A deterministic clip CDF reproduces the paper's Eq. (4) exactly."""
    q_first, q_last = 10, 22
    p = np.zeros((33, 33), np.float32)
    for m in range(33):
        p[m, min(max(m, q_first), q_last)] = 1.0
    cdf = jnp.asarray(np.cumsum(p, axis=1))
    wb, xb = rand_pm((16, 64)), rand_pm((64, 50))
    out = ref.submac_matmul_ref(wb, xb, cdf, ref.identity_vals(),
                                jnp.uint32(0), 0)
    lv = np.array(ref.submac_levels_ref(wb, xb))  # [O, G, D]
    clipped = np.clip(lv, q_first, q_last)
    expect = 2.0 * clipped.sum(axis=1) - 64.0
    np.testing.assert_array_equal(np.array(out), expect.astype(np.float32))


def test_partial_group_padding_is_nonconducting():
    """K not multiple of 32: pads contribute level 0 and beta subtraction
    recovers the exact valid dot product."""
    o, k, d = 8, 41, 13
    wb, xb = rand_pm((o, k)), rand_pm((k, d))
    wp, xp = ref.pad_operands(wb, xb)
    out = ref.submac_matmul_ref(wp, xp, ref.identity_cdf(),
                                ref.identity_vals(), jnp.uint32(2), 1,
                                beta=k)
    np.testing.assert_array_equal(np.array(out), np.array(wb @ xb))
    pout = submac.submac_matmul_pallas(wp, xp, ref.identity_cdf(),
                                       ref.identity_vals(), jnp.uint32(2),
                                       1, beta=k)
    np.testing.assert_array_equal(np.array(pout), np.array(wb @ xb))


def test_levels_and_hist_consistent():
    wb, xb = rand_pm((12, 96), ), rand_pm((96, 30))
    lv = np.array(ref.submac_levels_ref(wb, xb))
    hist = np.array(ref.submac_hist(wb, xb))
    assert hist.sum() == lv.size
    counts = np.bincount(lv.ravel(), minlength=33)
    np.testing.assert_array_equal(hist, counts.astype(np.float32))
    assert lv.min() >= 0 and lv.max() <= 32


def test_stochastic_respects_transition_matrix():
    """Empirical transition frequencies converge to the CDF's PMF."""
    p = np.zeros((33, 33), np.float32)
    p[:, :] = 0.0
    for m in range(33):
        p[m, m] = 0.7
        p[m, min(m + 1, 32)] += 0.2
        p[m, max(m - 1, 0)] += 0.1
    cdf = jnp.asarray(np.cumsum(p, axis=1))
    wb, xb = rand_pm((32, 32)), rand_pm((32, 512))
    lv = np.array(ref.submac_levels_ref(wb, xb))[:, 0, :]
    outs = []
    for seed in range(30):
        out = ref.submac_matmul_ref(wb, xb, cdf, ref.identity_vals(),
                                    jnp.uint32(seed), 0)
        decoded = (np.array(out) + 32.0) / 2.0
        outs.append(decoded - lv)  # per-element level shift
    shifts = np.stack(outs).ravel()
    frac_same = (shifts == 0).mean()
    frac_up = (shifts == 1).mean()
    frac_dn = (shifts == -1).mean()
    # interior levels dominate; boundary rows fold +-1 mass inward
    assert abs(frac_same - 0.7) < 0.03
    assert abs(frac_up - 0.2) < 0.03
    assert abs(frac_dn - 0.1) < 0.03


def test_vmem_footprint_within_budget():
    """Default blocks keep the largest model layer under 8 MiB VMEM."""
    k_max = 4608  # fc1 of full-width vgg7: 512*3*3
    assert submac.vmem_footprint_bytes(k_max) < 8 * 1024 * 1024


def test_adaptive_block_o_defaults():
    """Default (adaptive) blocking must match explicit blocking and the
    oracle — the perf-pass block plan cannot change semantics."""
    wb, xb = rand_pm((150, 96)), rand_pm((96, 70))
    cdf = rand_cdf()
    base = ref.submac_matmul_ref(wb, xb, cdf, ref.identity_vals(),
                                 jnp.uint32(5), 4)
    auto = submac.submac_matmul_pallas(wb, xb, cdf, ref.identity_vals(),
                                       jnp.uint32(5), 4)
    np.testing.assert_array_equal(np.array(base), np.array(auto))
    assert submac.adaptive_block_o(150) == 128
    assert submac.adaptive_block_o(10) == 16
    assert submac.adaptive_block_o(64) == 64


def test_adaptive_blocks_raise_mxu_utilization():
    before = submac.mxu_utilization_estimate(block_o=32)
    after = submac.mxu_utilization_estimate(
        block_o=submac.adaptive_block_o(256))
    assert after >= 4 * before - 1e-9, (before, after)
