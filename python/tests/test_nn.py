"""L2 layer semantics: conv-as-patches equivalence, BN folding, and the
train-graph / hardware-graph agreement that the whole codesign rests on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, nn

RNG = np.random.default_rng(7)


def rand_pm(shape):
    return jnp.asarray(RNG.choice([-1.0, 1.0], shape).astype(np.float32))


def test_patches_match_conv():
    """im2col + matmul == lax.conv for every (stride, k) we use."""
    for k, stride, cin in [(3, 1, 2), (3, 2, 3), (1, 1, 4), (1, 2, 2)]:
        x = rand_pm((2, cin, 9, 9))
        w = rand_pm((5, cin, k, k))
        xp = nn._pad_same(x, k, stride)
        want = jax.lax.conv_general_dilated(
            xp, w, (stride, stride), 'VALID',
            dimension_numbers=('NCHW', 'OIHW', 'NCHW'))
        xm, (b, oh, ow) = nn._patches(x, k, stride)
        got = (w.reshape(5, -1) @ xm).reshape(5, b, oh, ow)\
            .transpose(1, 0, 2, 3)
        np.testing.assert_array_equal(np.array(want), np.array(got))


def test_bn_fold_matches_bn():
    gamma = jnp.asarray(RNG.normal(1.0, 0.3, 8).astype(np.float32))
    beta = jnp.asarray(RNG.normal(0.0, 0.5, 8).astype(np.float32))
    mean = jnp.asarray(RNG.normal(0.0, 2.0, 8).astype(np.float32))
    var = jnp.asarray(RNG.uniform(0.5, 4.0, 8).astype(np.float32))
    x = jnp.asarray(RNG.normal(0, 3, (4, 8, 5, 5)).astype(np.float32))
    scale, bias = nn.bn_fold(gamma, beta, mean, var)
    want = (x - mean.reshape(1, -1, 1, 1)) / \
        jnp.sqrt(var.reshape(1, -1, 1, 1) + nn.BN_EPS) \
        * gamma.reshape(1, -1, 1, 1) + beta.reshape(1, -1, 1, 1)
    got = x * scale.reshape(1, -1, 1, 1) + bias.reshape(1, -1, 1, 1)
    np.testing.assert_allclose(np.array(want), np.array(got),
                               rtol=1e-5, atol=1e-5)


def test_ste_sign_values_and_grad():
    x = jnp.asarray([-2.0, -0.0, 0.0, 0.5, 3.0])
    np.testing.assert_array_equal(
        np.array(nn.ste_sign(x)), [-1.0, 1.0, 1.0, 1.0, 1.0])
    g = jax.grad(lambda v: jnp.sum(nn.ste_sign(v) * 2.0))(x)
    np.testing.assert_array_equal(np.array(g), np.full(5, 2.0))


@pytest.mark.parametrize('mname', ['vgg3_tiny'])
def test_eval_engines_agree(mname):
    """exact == jnp == pallas under the identity error model, end to end."""
    cfg = configs.model_configs()[mname]
    spec = configs.build_spec(cfg)
    key = jax.random.PRNGKey(3)
    params, state, _, _ = nn.init_model(key, spec, cfg['in_shape'])
    # give BN state non-trivial values so folding is actually exercised
    state = [s + 0.1 * (i + 1) for i, s in enumerate(state)]
    folded, _ = nn.export_folded(spec, params, state)
    x = rand_pm((4,) + cfg['in_shape'])
    from compile.kernels import ref as kref
    n_mat = nn.count_matmuls(spec)
    cdf = jnp.stack([kref.identity_cdf()] * n_mat)
    vals = jnp.stack([kref.identity_vals()] * n_mat)
    outs = {}
    for engine in ('exact', 'jnp', 'pallas'):
        eng = nn.SubMacEngine(engine, cdf, vals, jnp.uint32(11))
        outs[engine] = np.array(nn.forward_eval(spec, folded, x, eng))
    np.testing.assert_allclose(outs['exact'], outs['jnp'],
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(outs['jnp'], outs['pallas'])


def test_eval_stochastic_engines_bit_identical():
    cfg = configs.model_configs()['vgg3_tiny']
    spec = configs.build_spec(cfg)
    params, state, _, _ = nn.init_model(
        jax.random.PRNGKey(5), spec, cfg['in_shape'])
    folded, _ = nn.export_folded(spec, params, state)
    x = rand_pm((2,) + cfg['in_shape'])
    p = RNG.dirichlet(np.ones(33) * 0.5, size=33).astype(np.float32)
    cdf2 = np.cumsum(p, axis=1)
    cdf2[:, -1] = 1.0
    n_mat = nn.count_matmuls(spec)
    cdf = jnp.stack([jnp.asarray(cdf2)] * n_mat)
    from compile.kernels import ref as kref
    vals = jnp.stack([kref.identity_vals()] * n_mat)
    a = nn.forward_eval(spec, folded, x,
                        nn.SubMacEngine('jnp', cdf, vals, jnp.uint32(4)))
    b = nn.forward_eval(spec, folded, x,
                        nn.SubMacEngine('pallas', cdf, vals, jnp.uint32(4)))
    np.testing.assert_array_equal(np.array(a), np.array(b))


def test_folded_weights_are_pm_one_and_padded():
    cfg = configs.model_configs()['vgg3_tiny']
    spec = configs.build_spec(cfg)
    params, state, _, _ = nn.init_model(
        jax.random.PRNGKey(1), spec, cfg['in_shape'])
    folded, names = nn.export_folded(spec, params, state)
    for t, n in zip(folded, names):
        if n.startswith('wb'):
            assert t.shape[1] % 32 == 0
            vals = np.unique(np.array(t))
            assert set(vals.tolist()) <= {-1.0, 1.0}


def test_count_matmuls():
    cfgs = configs.model_configs()
    for name, want in [('vgg3', 4), ('vgg7', 8)]:
        spec = configs.build_spec(cfgs[name])
        assert nn.count_matmuls(spec) == want
    spec = configs.build_spec(cfgs['resnet18'])
    assert nn.count_matmuls(spec) == 1 + 4 * 3 + 1  # stem + 4 SCBs + out


def test_centered_pad_properties():
    """Dummy-cell biasing: partial groups center on the peak and the
    effective beta compensates exactly."""
    from compile.kernels import ref as kref
    for beta in [9, 27, 41, 72, 144, 392, 288]:
        p_on, beta_eff = nn.centered_pad(beta)
        r = beta % 32
        if r == 0:
            assert (p_on, beta_eff) == (0, beta)
        else:
            assert abs((p_on + r / 2.0) - 16.0) <= 1.0
            assert beta_eff == beta + 2 * p_on
        # end-to-end: padded rows + beta_eff recover the exact dot
        wb = rand_pm((4, beta))
        xm = rand_pm((beta, 6))
        wbp = nn._pad_w(wb)
        xmp, be = nn._pad_x_rows(xm)
        assert be == beta_eff
        out = kref.submac_matmul_ref(
            wbp, xmp, kref.identity_cdf(), kref.identity_vals(),
            jnp.asarray(0, jnp.uint32), 0, beta=be)
        np.testing.assert_array_equal(np.array(out), np.array(wb.T.T @ xm))


def test_partial_group_levels_centered():
    """After biasing, a beta=9 matmul's levels sit inside [10, 22]."""
    from compile.kernels import ref as kref
    wb = rand_pm((8, 9))
    xm = rand_pm((9, 50))
    wbp = nn._pad_w(wb)
    xmp, _ = nn._pad_x_rows(xm)
    lv = np.array(kref.submac_levels_ref(wbp, xmp))
    assert lv.min() >= 10 and lv.max() <= 22, (lv.min(), lv.max())
