"""Train step, export, and hist graphs behave as the Rust coordinator
assumes: loss decreases, shapes match, histograms count every sub-MAC."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import configs, model, nn

RNG = np.random.default_rng(11)


def _toy_batch(cfg, n, cls_sep=True):
    """Linearly separable +-1 images: class c gets a distinctive corner
    patch sign pattern."""
    c, h, w = cfg['in_shape']
    ncls = cfg['n_classes']
    y = RNG.integers(0, ncls, n)
    x = RNG.choice([-1.0, 1.0], (n, c, h, w)).astype(np.float32)
    if cls_sep:
        for i in range(n):
            cl = y[i]
            pat = np.where(
                (np.arange(h * w).reshape(h, w) // (cl + 1)) % 2 == 0,
                1.0, -1.0)
            x[i, 0, :, :] = pat  # strong per-class structure
    y_pm = -np.ones((n, ncls), np.float32)
    y_pm[np.arange(n), y] = 1.0
    return jnp.asarray(x), jnp.asarray(y_pm), jnp.asarray(y)


def test_train_step_decreases_loss():
    cfg = configs.model_configs()['vgg3_tiny']
    spec = configs.build_spec(cfg)
    params, state, _, _ = nn.init_model(
        jax.random.PRNGKey(0), spec, cfg['in_shape'])
    from compile import train as tr
    step_fn = jax.jit(tr.make_train_step(spec, tr.margin_for(spec, cfg['in_shape'])))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    x, y_pm, _ = _toy_batch(cfg, 16)
    losses = []
    for i in range(1, 61):
        params, state, m, v, loss = step_fn(
            params, state, m, v, jnp.float32(i), jnp.float32(5e-3),
            x, y_pm)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.92, (losses[0], losses[-1])


def test_trained_model_classifies_toy_data():
    cfg = configs.model_configs()['vgg3_tiny']
    spec = configs.build_spec(cfg)
    params, state, _, _ = nn.init_model(
        jax.random.PRNGKey(0), spec, cfg['in_shape'])
    from compile import train as tr
    step_fn = jax.jit(tr.make_train_step(spec, tr.margin_for(spec, cfg['in_shape'])))
    acc_fn = jax.jit(tr.make_accuracy(spec))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    for i in range(1, 81):
        x, y_pm, _ = _toy_batch(cfg, 32)
        params, state, m, v, _ = step_fn(
            params, state, m, v, jnp.float32(i), jnp.float32(1e-2),
            x, y_pm)
    x, _, y = _toy_batch(cfg, 64)
    acc = float(acc_fn(params, state, x, y))
    assert acc > 0.5, acc  # 10-way, separable -> way above chance

    # hardware-mode eval of the same trained model agrees with train graph
    folded, _ = nn.export_folded(spec, params, state)
    from compile.kernels import ref as kref
    eng = nn.SubMacEngine('exact', kref.identity_cdf(),
                          kref.identity_vals(), jnp.uint32(0))
    logits_hw = nn.forward_eval(spec, folded, x, eng)
    acc_hw = float(jnp.mean(
        (jnp.argmax(logits_hw, 1) == y).astype(jnp.float32)))
    # BN uses batch stats in train graph vs running stats in hw graph, so
    # agreement is statistical, not exact.
    assert acc_hw > 0.4, (acc, acc_hw)


def test_hist_counts_every_submac():
    cfg = configs.model_configs()['vgg3_tiny']
    spec = configs.build_spec(cfg)
    params, state, _, _ = nn.init_model(
        jax.random.PRNGKey(2), spec, cfg['in_shape'])
    folded, _ = nn.export_folded(spec, params, state)
    b = 4
    x = jnp.asarray(RNG.choice(
        [-1.0, 1.0], (b,) + cfg['in_shape']).astype(np.float32))
    hist_fn = model.make_hist(spec, len(folded))
    fmac, logits = hist_fn(*(folded + [x]))
    fmac = np.array(fmac)
    assert fmac.shape == (nn.count_matmuls(spec), 33)
    assert (fmac >= 0).all()
    # each matmul contributes O * G * D sub-MACs
    f = iter(folded)
    # first conv: O x (B*28*28) output positions, G=1 group
    wb0 = next(f)
    o0 = wb0.shape[0]
    g0 = wb0.shape[1] // 32
    assert fmac[0].sum() == o0 * g0 * b * 28 * 28
    assert logits.shape == (b, cfg['n_classes'])


def test_folded_signature_matches_manifest_contract():
    cfg = configs.model_configs()['vgg3_tiny']
    spec = configs.build_spec(cfg)
    sig, _ = model.folded_signature(spec, cfg['in_shape'])
    names = [n for n, _ in sig]
    assert names[0] == 'wb0'
    assert 'out.b' == names[-1]
    assert any(n.startswith('scale') for n in names)
