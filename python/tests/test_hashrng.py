"""Counter-based PRNG: determinism, range, uniformity, decorrelation."""

import jax.numpy as jnp
import numpy as np

from compile.kernels import hashrng


def _u(seed, n, offset=0):
    idx = jnp.arange(offset, offset + n, dtype=jnp.uint32)
    return np.array(hashrng.hash01(jnp.uint32(seed), idx))


def test_range_and_determinism():
    u1 = _u(123, 10000)
    u2 = _u(123, 10000)
    assert (u1 == u2).all()
    assert (u1 >= 0.0).all() and (u1 < 1.0).all()


def test_uniform_moments():
    u = _u(7, 200000)
    assert abs(u.mean() - 0.5) < 5e-3
    assert abs(u.var() - 1.0 / 12.0) < 5e-3


def test_histogram_flat():
    u = _u(99, 200000)
    counts, _ = np.histogram(u, bins=20, range=(0, 1))
    assert counts.min() > 0.9 * 200000 / 20
    assert counts.max() < 1.1 * 200000 / 20


def test_seed_decorrelation():
    a = _u(1, 50000)
    b = _u(2, 50000)
    corr = np.corrcoef(a, b)[0, 1]
    assert abs(corr) < 0.02


def test_adjacent_index_decorrelation():
    u = _u(5, 100001)
    corr = np.corrcoef(u[:-1], u[1:])[0, 1]
    assert abs(corr) < 0.02


def test_no_trivial_collision_burst():
    h = np.array(hashrng.hash_u32(
        jnp.uint32(3), jnp.arange(100000, dtype=jnp.uint32)))
    # murmur finalizer is a bijection over the mixed stream; duplicates can
    # only come from the +seed*GOLDEN pre-mix, which is also injective.
    assert len(np.unique(h)) == len(h)
