"""L2 entry points lowered by aot.py — one pure function per artifact kind.

Artifact kinds (all per model config; shapes are static):

  init   (key u32[2])                          -> params..., state...
  train  (params..., state..., m..., v...,
          step f32, lr f32, x, y_pm)           -> params', state', m', v',
                                                  loss
  export (params..., state...)                 -> folded hardware tensors
  hist   (folded..., x)                        -> per-matmul F_MAC [n,33],
                                                  logits
  eval   (folded..., x, cdf, vals, seed u32)   -> logits   (jnp engine)
  evalp  (folded..., x, cdf, vals, seed u32)   -> logits   (Pallas engine)

`folded` = export's output list: per-matmul +-1 padded weights, per-BN
digital affines, final bias. The error model (cdf/vals) and the PRNG seed
are *runtime inputs*, so the Rust coordinator sweeps CapMin's k and
CapMin-V's phi without recompiling.
"""

import jax
import jax.numpy as jnp

from . import nn, train
from .kernels import ref as kref


def make_init(spec, in_shape):
    def init(key):
        params, state, _, _ = nn.init_model(key, spec, in_shape)
        return tuple(params) + tuple(state)

    return init


def make_train_fn(spec, n_params, n_state, mhl_b=None):
    if mhl_b is None:
        mhl_b = train.MHL_B
    step_fn = train.make_train_step(spec, mhl_b)

    def train_fn(*args):
        params = list(args[:n_params])
        state = list(args[n_params:n_params + n_state])
        off = n_params + n_state
        m = list(args[off:off + n_params])
        v = list(args[off + n_params:off + 2 * n_params])
        step, lr, x, y_pm = args[off + 2 * n_params:]
        new_p, new_s, new_m, new_v, loss = step_fn(
            params, state, m, v, step, lr, x, y_pm)
        return tuple(new_p) + tuple(new_s) + tuple(new_m) + tuple(new_v) \
            + (loss,)

    return train_fn


def make_export(spec, n_params):
    def export(*args):
        params = list(args[:n_params])
        state = list(args[n_params:])
        out, _ = nn.export_folded(spec, params, state)
        return tuple(out)

    return export


def make_hist(spec, n_folded):
    def hist(*args):
        folded = list(args[:n_folded])
        x = args[n_folded]
        eng = nn.SubMacEngine('exact', None, None, None, hist=True)
        logits = nn.forward_eval(spec, folded, x, eng)
        return jnp.stack(eng.hists), logits

    return hist


def make_eval(spec, n_folded, engine):
    def eval_fn(*args):
        folded = list(args[:n_folded])
        x, cdf, vals, seed = args[n_folded:]
        eng = nn.SubMacEngine(engine, cdf, vals, seed)
        return nn.forward_eval(spec, folded, x, eng)

    return eval_fn


def folded_signature(spec, in_shape, key=None):
    """Shapes/names of the folded tensors (drives the AOT manifest)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    params, state, pnames, snames = nn.init_model(key, spec, in_shape)
    out, names = nn.export_folded(spec, params, state)
    return [(n, tuple(t.shape)) for n, t in zip(names, out)], \
        (params, state, pnames, snames)


def identity_error_model():
    return kref.identity_cdf(), kref.identity_vals()
