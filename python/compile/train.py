"""Training-path graph: modified hinge loss + Adam over latent weights.

The paper trains BNNs with Adam and the modified hinge loss (MHL, b=128,
Buschjäger et al. DATE'21) for margin-maximization, which is also what
gives BNNs their error tolerance. This module builds the *pure* train-step
function that `aot.py` lowers to HLO; the Rust coordinator owns the loop,
the LR schedule (halving per the paper), batching and logging.
"""

import jax
import jax.numpy as jnp

from . import nn

MHL_B = 128.0
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def mhl_loss(logits, y_pm, b=MHL_B):
    """Modified hinge loss. y_pm: [B, C] targets in {-1,+1} (+1 = true
    class). mean over classes and batch of max(0, b - t*logit)/b.

    The margin b is capped by the caller to the output layer's fan-in:
    a +-1 FC with K inputs can only produce |logit| <= K, so the paper's
    b=128 is unreachable for narrow models and would flatten the loss."""
    return jnp.mean(jnp.maximum(0.0, b - y_pm * logits)) / b


def adam_update(p, g, m, v, step, lr):
    m = ADAM_B1 * m + (1 - ADAM_B1) * g
    v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m / (1 - ADAM_B1 ** step)
    vhat = v / (1 - ADAM_B2 ** step)
    return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v


def margin_for(spec, in_shape):
    """Margin b = min(128, fan-in of the output FC).

    Walks the spec with the same shape inference as nn.init_model."""
    c, h, w = in_shape
    flat = None
    for op in spec:
        kind = op[0]
        if kind == 'conv':
            c, h, w = op[1], -(-h // op[2]), -(-w // op[2])
        elif kind == 'scb':
            c, h, w = op[1], -(-h // op[2]), -(-w // op[2])
        elif kind == 'mp':
            h, w = h // op[1], w // op[1]
        elif kind == 'flatten':
            flat = c * h * w
        elif kind == 'fc':
            flat = op[1]
        elif kind == 'out':
            return float(min(MHL_B, flat))
    raise ValueError('spec has no out layer')


def make_train_step(spec, mhl_b=MHL_B):
    """Returns train_step(params, state, m, v, step, lr, x, y_pm) ->
    (params', state', m', v', loss). All lists are flat (AOT-friendly)."""

    def train_step(params, state, m, v, step, lr, x, y_pm):
        def loss_fn(ps):
            logits, new_state = nn.forward_train(spec, ps, state, x)
            return mhl_loss(logits, y_pm, mhl_b), new_state

        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, grads, m, v):
            pn, mn, vn = adam_update(p, g, mi, vi, step, lr)
            new_p.append(pn)
            new_m.append(mn)
            new_v.append(vn)
        return new_p, new_state, new_m, new_v, loss

    return train_step


def make_accuracy(spec):
    """Clean training-graph accuracy (used by the trainer's val hook)."""

    def acc_fn(params, state, x, y_idx):
        logits, _ = nn.forward_train(spec, params, state, x)
        return jnp.mean((jnp.argmax(logits, axis=1) == y_idx)
                        .astype(jnp.float32))

    return acc_fn
