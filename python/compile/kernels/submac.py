"""L1: Pallas sub-MAC kernel — the paper's custom MAC engine, for TPU.

The paper replaces PyTorch's closed-source GPU MAC engine with a custom
CUDA kernel so that clipping (CapMin, Eq. 4) and the variation error model
(CapMin-V, Eq. 6) can be applied at *sub-MAC* granularity (one a=32 XNOR
array invocation). This kernel is that engine rethought for TPU:

  * CUDA threadblock tiling        -> Pallas grid over (O-blocks, D-blocks)
    with BlockSpec index maps; the W tile and the error-model tables are
    grid-invariant along D, so Pallas keeps them resident in VMEM across
    grid steps (the analogue of caching weights in shared memory).
  * warp ballot/popcount           -> +-1 dot products over 32-wide groups;
    popcount(XNOR) == (32 + w.x)/2 exactly, and the 32xD times Ox32 group
    product maps onto the MXU systolic array on a real TPU.
  * shared-memory LUT + divergent
    branchy error sampling         -> the 33x33 row-CDF lives in VMEM
    (4.4 KiB) and sampling is a vectorised comparison scan (no divergence).

Lowered with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so correctness runs through the interpreter while the real-TPU
resource usage (VMEM footprint, MXU shapes) is estimated statically — see
DESIGN.md §7 and `vmem_footprint_bytes` below.

Bit-exactness: the kernel derives its per-sub-MAC uniforms from the same
counter-based hash over the same *logical* (o, g, d) indices as the jnp
oracle in `ref.py`, so `submac_matmul_pallas == submac_matmul_ref` exactly,
including in stochastic mode.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

from .hashrng import hash01
from .ref import ARRAY_SIZE, N_LEVELS

DEFAULT_BLOCK_O = 32
DEFAULT_BLOCK_D = 128


def adaptive_block_o(o):
    """Perf pass (EXPERIMENTS.md §Perf L1): one group matmul is
    (block_o x 32) @ (32 x block_d); with block_o = 32 a 128x128 MXU pass
    is only 32*32*128 / 128^3 = 6.2% utilized. Widening block_o to the
    output size (capped at 128, the MXU edge) packs 4x more useful work
    per pass for the wide layers (25% util; the 32-deep reduction is the
    a=32 array structure and cannot fill the remaining factor without
    fusing groups, which would break per-group read-out semantics)."""
    if o >= 128:
        return 128
    # round up to the next multiple of 8 (sublane) without exceeding 128
    return max(8, min(128, (o + 7) // 8 * 8))


def _kernel(w_ref, x_ref, cdf_ref, vals_ref, seed_ref, out_ref,
            *, n_groups, block_o, block_d, d_logical, salt, beta):
    """One (block_o x block_d) output tile.

    w_ref: [block_o, K] (grid-invariant along D). x_ref: [K, block_d].
    cdf_ref: [33, 33]; vals_ref: [33]; seed_ref: [1] u32.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    w = w_ref[...]
    x = x_ref[...]
    cdf = cdf_ref[...]
    vals = vals_ref[...]
    seed = seed_ref[0]

    # Logical coordinates of this tile's elements; used for the counter-based
    # PRNG so results are independent of the blocking (and identical to ref).
    oidx = (i * block_o +
            jnp.arange(block_o, dtype=jnp.uint32)[:, None])
    didx = (j * block_d +
            jnp.arange(block_d, dtype=jnp.uint32)[None, :])

    def body(g, acc):
        wg = jax.lax.dynamic_slice(w, (0, g * ARRAY_SIZE),
                                   (block_o, ARRAY_SIZE))
        xg = jax.lax.dynamic_slice(x, (g * ARRAY_SIZE, 0),
                                   (ARRAY_SIZE, block_d))
        dot = wg @ xg  # MXU-shaped on real TPU
        m = ((dot + ARRAY_SIZE) * 0.5).astype(jnp.int32)
        lin = (np.uint32(salt) +
               (oidx * np.uint32(n_groups) + g.astype(jnp.uint32)) *
               np.uint32(d_logical) + didx)
        u = hash01(seed, lin)

        def col_body(c, col):
            # right-continuous CDF inversion (see ref.decode_levels)
            return col + (jnp.take(cdf[:, c], m, axis=0) <= u)\
                .astype(jnp.int32)

        col = jax.lax.fori_loop(0, N_LEVELS, col_body, jnp.zeros_like(m))
        dv = jnp.take(vals, col, axis=0)
        return acc + 2.0 * dv

    acc = jax.lax.fori_loop(
        0, n_groups, body,
        jnp.zeros((block_o, block_d), dtype=jnp.float32))
    out_ref[...] = acc - np.float32(beta)


def submac_matmul_pallas(wb, xb, cdf, vals, seed, salt, beta=None,
                         block_o=None, block_d=DEFAULT_BLOCK_D):
    """Pallas twin of `ref.submac_matmul_ref` (same signature + blocks).

    wb: [O, K] +-1 f32 with K % 32 == 0; xb: [K, D] +-1 f32.
    Output [O, D] f32. O and D are padded up to block multiples internally
    (pads are non-conducting and sliced off), so any shape is accepted.

    The kernel subtracts n_groups*32 == K at the end, matching ref.py
    exactly (K here is already group-padded; O/D pads added below are
    non-conducting cells whose outputs are sliced off).
    """
    o, k = wb.shape
    d = xb.shape[1]
    assert k % ARRAY_SIZE == 0, "pad reduction dim with pad_operands first"
    if beta is None:
        beta = k
    if block_o is None:
        block_o = adaptive_block_o(o)
    n_groups = k // ARRAY_SIZE
    op = (o + block_o - 1) // block_o * block_o
    dp = (d + block_d - 1) // block_d * block_d
    if op != o:
        wb = jnp.pad(wb, ((0, op - o), (0, 0)), constant_values=1.0)
    if dp != d:
        xb = jnp.pad(xb, ((0, 0), (0, dp - d)), constant_values=-1.0)
    seed_arr = jnp.asarray(seed, dtype=jnp.uint32).reshape((1,))

    kernel = functools.partial(
        _kernel, n_groups=n_groups, block_o=block_o, block_d=block_d,
        d_logical=d, salt=salt, beta=beta)
    out = pl.pallas_call(
        kernel,
        grid=(op // block_o, dp // block_d),
        in_specs=[
            pl.BlockSpec((block_o, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_d), lambda i, j: (0, j)),
            pl.BlockSpec((N_LEVELS, N_LEVELS), lambda i, j: (0, 0)),
            pl.BlockSpec((N_LEVELS,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_o, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((op, dp), jnp.float32),
        interpret=True,
    )(wb, xb, cdf, vals, seed_arr)
    return out[:o, :d]


def vmem_footprint_bytes(k, block_o=DEFAULT_BLOCK_O, block_d=DEFAULT_BLOCK_D):
    """Static VMEM estimate per grid step (real-TPU sizing, DESIGN.md §7).

    W tile + X tile + CDF/vals tables + accumulator + level/uniform temps.
    """
    f32 = 4
    w_tile = block_o * k * f32
    x_tile = k * block_d * f32
    tables = (N_LEVELS * N_LEVELS + N_LEVELS) * f32
    acc = block_o * block_d * f32
    temps = 3 * block_o * block_d * f32  # dot/m/u live ranges overlap acc
    return w_tile + x_tile + tables + acc + temps


def mxu_utilization_estimate(block_o=DEFAULT_BLOCK_O,
                             block_d=DEFAULT_BLOCK_D):
    """Fraction of a 128x128 MXU pass doing useful work for one group
    matmul tile (block_o x 32) @ (32 x block_d)."""
    useful = block_o * ARRAY_SIZE * block_d
    passes_o = (block_o + 127) // 128
    passes_d = (block_d + 127) // 128
    full = passes_o * passes_d * 128 * 128 * 128
    return useful / full
