"""Counter-based hash PRNG shared by the Pallas kernel and the jnp reference.

The error-injection path needs one uniform sample per sub-MAC result. A
counter-based hash (murmur3 finalizer over a linear index mixed with a seed)
keeps the AOT graphs stateless: Rust passes a u32 seed per forward pass and
the kernel derives every sample from (seed, logical position). Because the
reference oracle (`ref.py`) and the Pallas kernel (`submac.py`) use the same
hash over the same logical indices, their stochastic outputs are
*bit-identical*, which turns stochastic-mode testing into exact comparison.
"""

import jax.numpy as jnp
import numpy as np

_GOLDEN = np.uint32(0x9E3779B9)
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)


def hash_u32(seed, idx):
    """Murmur3 finalizer over a u32 index stream, keyed by `seed`.

    seed: scalar uint32 (or broadcastable). idx: uint32 array of logical
    positions. Returns uint32 array of well-mixed words.
    """
    x = idx.astype(jnp.uint32) + jnp.asarray(seed).astype(jnp.uint32) * _GOLDEN
    x = x ^ (x >> np.uint32(16))
    x = x * _M1
    x = x ^ (x >> np.uint32(13))
    x = x * _M2
    x = x ^ (x >> np.uint32(16))
    return x


def hash01(seed, idx):
    """Uniform f32 samples in [0, 1) derived from (seed, idx).

    Uses the top 24 bits so the f32 value is exact and strictly < 1.0
    (dividing the full 32-bit word by 2^32 can round up to 1.0 in f32,
    which would walk off the end of a CDF row).
    """
    h = hash_u32(seed, idx) >> np.uint32(8)
    return h.astype(jnp.float32) * np.float32(1.0 / (1 << 24))
