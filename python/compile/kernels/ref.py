"""Pure-jnp oracle for the sub-MAC engine.

This is the semantic ground truth for the Pallas kernel in `submac.py` and
for the Rust bit-packed engine (`rust/src/bnn/engine.rs`): a binarized
matmul computed at *sub-MAC granularity* — the granularity of the paper's
a=32 XNOR computing array — with the IF-SNN read-out model applied to every
sub-MAC level:

  1. split the reduction dimension into groups of ARRAY_SIZE=32 (the array),
  2. per group, the XNOR-popcount level  M = (32 + dot)/2  in [0, 32]
     (padding cells are (w=+1, x=-1) pairs, i.e. non-conducting: they
     contribute 0 to M, exactly like unused cells in a partially filled
     array),
  3. read-out through the spike-time error model: a row-stochastic 33x33
     CDF matrix maps the true level M to a decoded level (CapMin clipping
     and CapMin-V / Monte-Carlo variation are all expressed as this one
     matrix; the identity matrix is the ideal circuit),
  4. the digital accumulator sums decoded levels:  out = 2*sum_g D_g - beta.

Everything is f32; levels are small integers so the arithmetic is exact.
"""

import jax
import jax.numpy as jnp

from .hashrng import hash01

ARRAY_SIZE = 32
N_LEVELS = ARRAY_SIZE + 1  # sub-MAC levels 0..32


def pad_operands(wb, xb):
    """Pad the reduction dim of (wb: [O,K], xb: [K,D]) to a multiple of 32.

    Pads are non-conducting cells: w=+1 rows against x=-1 columns contribute
    -1 to the group dot product and therefore 0 to the popcount level M.
    """
    k = wb.shape[1]
    kp = (k + ARRAY_SIZE - 1) // ARRAY_SIZE * ARRAY_SIZE
    if kp != k:
        wb = jnp.pad(wb, ((0, 0), (0, kp - k)), constant_values=1.0)
        xb = jnp.pad(xb, ((0, kp - k), (0, 0)), constant_values=-1.0)
    return wb, xb


def identity_cdf():
    """CDF of the ideal (error-free) read-out: level M decodes to M."""
    return jnp.cumsum(jnp.eye(N_LEVELS, dtype=jnp.float32), axis=1)


def identity_vals():
    """Decoded value of each read-out column under the ideal circuit."""
    return jnp.arange(N_LEVELS, dtype=jnp.float32)


def decode_levels(m, cdf, vals, u):
    """Map true levels `m` (int32) to decoded values via CDF inversion.

    col = #{c : cdf[m, c] <= u}; decoded = vals[col]. (`<=`, not `<`: with
    `<` a sample u exactly 0 would land in a zero-probability prefix
    column; `<=` is the correct right-continuous CDF inversion and gives
    P(col=j) = cdf[j] - cdf[j-1] for u ~ U[0,1).) The 33-column scan is
    expressed as a fori_loop so no [..., 33] gather tensor is materialised
    (on the jnp batch path that would be GiB-scale).
    """
    def body(c, col):
        return col + (jnp.take(cdf[:, c], m, axis=0) <= u).astype(jnp.int32)

    col = jax.lax.fori_loop(0, N_LEVELS, body, jnp.zeros_like(m))
    return jnp.take(vals, col, axis=0)


def submac_matmul_ref(wb, xb, cdf, vals, seed, salt, beta=None):
    """Binarized matmul with per-sub-MAC error injection (jnp oracle).

    wb: [O, K] in {-1,+1} f32 (K a multiple of 32 — use `pad_operands`).
    xb: [K, D] in {-1,+1} f32.
    cdf: [33, 33] row-CDF of the level-transition matrix (rows: true level).
    vals: [33] decoded value of each column (f32).
    seed: scalar uint32; salt: python int, decorrelates call sites.
    beta: true (pre-padding) reduction length the digital accumulator
    subtracts; defaults to K. Pad cells are non-conducting (level
    contribution 0), so with beta = true K the result equals the valid
    dot product exactly under the identity CDF.
    Returns [O, D] f32: 2 * sum_g decoded_g - beta.
    """
    o, k = wb.shape
    if beta is None:
        beta = k
    d = xb.shape[1]
    g = k // ARRAY_SIZE
    w3 = wb.reshape(o, g, ARRAY_SIZE)
    x3 = xb.reshape(g, ARRAY_SIZE, d)
    salt = jnp.uint32(salt)
    seed = jnp.asarray(seed, dtype=jnp.uint32)

    def body(gi, acc):
        wg = jax.lax.dynamic_index_in_dim(w3, gi, 1, keepdims=False)
        xg = jax.lax.dynamic_index_in_dim(x3, gi, 0, keepdims=False)
        dot = wg @ xg
        m = ((dot + ARRAY_SIZE) * 0.5).astype(jnp.int32)  # [O, D]
        oidx = jnp.arange(o, dtype=jnp.uint32)[:, None]
        didx = jnp.arange(d, dtype=jnp.uint32)[None, :]
        lin = salt + (oidx * jnp.uint32(g) + gi.astype(jnp.uint32)) \
            * jnp.uint32(d) + didx
        u = hash01(seed, lin)
        dv = decode_levels(m, cdf, vals, u)
        return acc + 2.0 * dv

    acc = jax.lax.fori_loop(0, g, body,
                            jnp.zeros((o, d), dtype=jnp.float32))
    return acc - jnp.float32(beta)


def submac_levels_ref(wb, xb):
    """True sub-MAC levels [O, G, D] (int32), for tests and histograms."""
    o, k = wb.shape
    d = xb.shape[1]
    g = k // ARRAY_SIZE
    w3 = wb.reshape(o, g, ARRAY_SIZE)
    x3 = xb.reshape(g, ARRAY_SIZE, d)
    dot = jnp.einsum('ogk,gkd->ogd', w3, x3)
    return ((dot + ARRAY_SIZE) * 0.5).astype(jnp.int32)


def submac_hist(wb, xb):
    """Absolute frequency of occurrence of sub-MAC levels: [33] f32 counts.

    One matmul's contribution to the paper's F_MAC histograms (Fig. 1).
    """
    o, k = wb.shape
    d = xb.shape[1]
    g = k // ARRAY_SIZE
    w3 = wb.reshape(o, g, ARRAY_SIZE)
    x3 = xb.reshape(g, ARRAY_SIZE, d)

    def body(gi, hist):
        wg = jax.lax.dynamic_index_in_dim(w3, gi, 1, keepdims=False)
        xg = jax.lax.dynamic_index_in_dim(x3, gi, 0, keepdims=False)
        dot = wg @ xg
        m = ((dot + ARRAY_SIZE) * 0.5).astype(jnp.int32)
        onehot = (m[:, :, None] ==
                  jnp.arange(N_LEVELS, dtype=jnp.int32)).astype(jnp.float32)
        return hist + onehot.sum(axis=(0, 1))

    return jax.lax.fori_loop(
        0, g, body, jnp.zeros((N_LEVELS,), dtype=jnp.float32))
