"""Model and dataset registry — Table I / Table II of the paper, plus the
CPU-budget scaling this reproduction runs by default.

`full=True` restores the paper's exact widths (Table II); the default
configs scale channel counts down so the single-core CPU testbed can train
and sweep all five benchmarks inside the experiment budget. Architecture,
depth, input shapes, class counts, and the a=32 sub-MAC structure are
identical in both modes (DESIGN.md §6).
"""

from . import arch


def model_configs(full=False):
    w = 1.0 if full else 0.5
    w7 = 1.0 if full else 0.25
    wr = 1.0 if full else 0.25
    fc = 1.0 if full else 0.25
    return {
        'vgg3': dict(
            arch='vgg3', width=w, fc_width=fc, in_shape=(1, 28, 28),
            train_batch=64, eval_batch=16, hist_batch=32, n_classes=10),
        'vgg7': dict(
            arch='vgg7', width=w7, fc_width=fc, in_shape=(3, 32, 32),
            train_batch=32, eval_batch=8, hist_batch=16, n_classes=10),
        'resnet18': dict(
            arch='resnet18', width=wr, fc_width=1.0, in_shape=(3, 64, 64),
            train_batch=16, eval_batch=8, hist_batch=8, n_classes=10),
        # tiny twin of vgg3 used by fast tests and the quickstart example
        'vgg3_tiny': dict(
            arch='vgg3', width=0.125, fc_width=32 / 2048,
            in_shape=(1, 28, 28), train_batch=16, eval_batch=8,
            hist_batch=8, n_classes=10),
    }


# Table I: dataset name -> (model, generator id, #train, #test).
# The generators are procedural synthetic equivalents built in
# rust/src/data/ (no dataset downloads in this environment; DESIGN.md §6).
DATASETS = {
    'fashion_syn': dict(model='vgg3', shape=(1, 28, 28), classes=10,
                        n_train=60000, n_test=10000, paper='FashionMNIST'),
    'kmnist_syn': dict(model='vgg3', shape=(1, 28, 28), classes=10,
                       n_train=60000, n_test=10000, paper='KuzushijiMNIST'),
    'svhn_syn': dict(model='vgg7', shape=(3, 32, 32), classes=10,
                     n_train=73257, n_test=26032, paper='SVHN'),
    'cifar_syn': dict(model='vgg7', shape=(3, 32, 32), classes=10,
                      n_train=50000, n_test=10000, paper='CIFAR10'),
    'imagenette_syn': dict(model='resnet18', shape=(3, 64, 64), classes=10,
                           n_train=9470, n_test=3925, paper='Imagenette'),
}


def build_spec(cfg):
    builder = arch.ARCH_BUILDERS[cfg['arch']]
    if cfg['arch'] == 'resnet18':
        return builder(width=cfg['width'])
    return builder(width=cfg['width'], fc_width=cfg['fc_width'])
