"""BNN building blocks (L2): training-mode layers, export-time folding,
and the grouped sub-MAC evaluation path that calls the L1 kernel.

Three views of the same network:

  * `forward_train`  — float latent weights, STE binarization, live batch
    norm. Used by the AOT train-step artifact (the Rust trainer drives it).
  * `export_folded`  — freezes a trained model into exactly what the
    IF-SNN hardware stores: +-1 weight matrices padded to a=32 groups and
    per-channel digital affines (BN folded; sign(BN(x)) == sign(ax+b)).
  * `forward_eval`   — the hardware-mode forward pass: every binarized
    matmul runs at sub-MAC granularity through the error model, via either
    the jnp oracle (`engine='jnp'`), the Pallas kernel (`engine='pallas'`),
    or the idealized fast path (`engine='exact'`, no grouping — used for
    clean-accuracy baselines and tests).

Conventions: NCHW activations, OIHW weights, +-1 binary domain (SAME
padding pads with -1: the binary domain has no zero, and the padded cells
behave as non-conducting array cells, mirroring the hardware).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref as kref
from .kernels import submac as ksub

BN_EPS = 1e-5
BN_MOMENTUM = 0.9
_SALT_STRIDE = 0x9E3779B1  # decorrelates per-matmul PRNG streams


def ste_sign(x):
    """Binarize to {-1,+1} with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.where(x >= 0, 1.0, -1.0) - x)


def _pad_same(x, k, stride):
    """Explicit SAME padding with -1 (binary 'off'), NCHW."""
    h, w = x.shape[2], x.shape[3]
    oh = -(-h // stride)
    ow = -(-w // stride)
    ph = max(0, (oh - 1) * stride + k - h)
    pw = max(0, (ow - 1) * stride + k - w)
    return jnp.pad(x, ((0, 0), (0, 0),
                       (ph // 2, ph - ph // 2),
                       (pw // 2, pw - pw // 2)),
                   constant_values=-1.0)


def conv_bin(x, w_latent, stride, k):
    """Training-mode binarized conv: STE weights, -1-padded SAME."""
    wb = ste_sign(w_latent)
    xp = _pad_same(x, k, stride)
    return jax.lax.conv_general_dilated(
        xp, wb, (stride, stride), 'VALID',
        dimension_numbers=('NCHW', 'OIHW', 'NCHW'))


def maxpool(x, k):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, k, k), 'VALID')


def bn_train(x, gamma, beta, mean, var):
    """Batch norm over (N, H, W) or (N,); returns (y, new_mean, new_var)."""
    axes = (0, 2, 3) if x.ndim == 4 else (0,)
    mu = jnp.mean(x, axis=axes)
    sig2 = jnp.var(x, axis=axes)
    shape = (1, -1, 1, 1) if x.ndim == 4 else (1, -1)
    y = (x - mu.reshape(shape)) / jnp.sqrt(sig2.reshape(shape) + BN_EPS)
    y = y * gamma.reshape(shape) + beta.reshape(shape)
    new_mean = BN_MOMENTUM * mean + (1 - BN_MOMENTUM) * mu
    new_var = BN_MOMENTUM * var + (1 - BN_MOMENTUM) * sig2
    return y, new_mean, new_var


def bn_fold(gamma, beta, mean, var):
    """BN -> digital affine: y = scale*x + bias (DESIGN.md §4).

    sign(BN(x)) == sign(scale*x + bias), and at branch merges the affine
    is what the digital accumulator applies to decoded MAC values.
    """
    scale = gamma / jnp.sqrt(var + BN_EPS)
    bias = beta - scale * mean
    return scale, bias


# --------------------------------------------------------------------------
# Parameter initialization (walks the arch spec, returns flat lists).
# --------------------------------------------------------------------------

def _glorot(key, shape):
    fan_in = int(np.prod(shape[1:]))
    fan_out = shape[0]
    s = np.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype=jnp.float32) * s


def init_model(key, spec, in_shape):
    """Initialize latent params and BN state for an arch spec.

    Returns (params, state, pnames, snames): flat lists of f32 arrays plus
    their names (the AOT manifest records names/shapes for the Rust side).
    """
    params, state, pnames, snames = [], [], [], []
    c, h, w = in_shape

    def add_p(name, arr):
        params.append(arr)
        pnames.append(name)

    def add_bn(name, ch):
        add_p(f'{name}.gamma', jnp.ones((ch,), jnp.float32))
        add_p(f'{name}.beta', jnp.zeros((ch,), jnp.float32))
        state.append(jnp.zeros((ch,), jnp.float32))
        snames.append(f'{name}.mean')
        state.append(jnp.ones((ch,), jnp.float32))
        snames.append(f'{name}.var')

    li = 0
    flat = None
    for op in spec:
        kind = op[0]
        if kind == 'conv':
            oc, s = op[1], op[2]
            key, sub = jax.random.split(key)
            add_p(f'conv{li}.w', _glorot(sub, (oc, c, 3, 3)))
            c, h, w = oc, -(-h // s), -(-w // s)
            li += 1
        elif kind == 'mp':
            h, w = h // op[1], w // op[1]
        elif kind == 'bn':
            add_bn(f'bn{li - 1}', c if flat is None else flat)
        elif kind == 'sign':
            pass
        elif kind == 'scb':
            oc, s = op[1], op[2]
            key, k1, k2, k3 = jax.random.split(key, 4)
            add_p(f'scb{li}.w1', _glorot(k1, (oc, c, 3, 3)))
            add_bn(f'scb{li}.bn1', oc)
            add_p(f'scb{li}.w2', _glorot(k2, (oc, oc, 3, 3)))
            add_bn(f'scb{li}.bn2', oc)
            add_p(f'scb{li}.wp', _glorot(k3, (oc, c, 1, 1)))
            add_bn(f'scb{li}.bnp', oc)
            c, h, w = oc, -(-h // s), -(-w // s)
            li += 1
        elif kind == 'flatten':
            flat = c * h * w
        elif kind == 'fc':
            key, sub = jax.random.split(key)
            add_p(f'fc{li}.w', _glorot(sub, (op[1], flat)))
            flat = op[1]
            li += 1
        elif kind == 'out':
            key, sub = jax.random.split(key)
            add_p(f'out.w', _glorot(sub, (op[1], flat)))
            add_p(f'out.b', jnp.zeros((op[1],), jnp.float32))
        else:
            raise ValueError(f'unknown op {kind}')
    return params, state, pnames, snames


# --------------------------------------------------------------------------
# Training-mode forward.
# --------------------------------------------------------------------------

def forward_train(spec, params, state, x):
    """Training forward pass. x: NCHW +-1. Returns (logits, new_state)."""
    p = iter(params)
    new_state = []
    st = iter(state)

    def bn(y):
        gamma, beta = next(p), next(p)
        mean, var = next(st), next(st)
        y, nm, nv = bn_train(y, gamma, beta, mean, var)
        new_state.extend([nm, nv])
        return y

    h = x
    for op in spec:
        kind = op[0]
        if kind == 'conv':
            h = conv_bin(h, next(p), op[2], 3)
        elif kind == 'mp':
            h = maxpool(h, op[1])
        elif kind == 'bn':
            h = bn(h)
        elif kind == 'sign':
            h = ste_sign(h)
        elif kind == 'scb':
            s = op[2]
            y = ste_sign(bn(conv_bin(h, next(p), s, 3)))
            z = bn(conv_bin(y, next(p), 1, 3))
            sc = bn(conv_bin(h, next(p), s, 1))
            h = ste_sign(z + sc)
        elif kind == 'flatten':
            h = h.reshape(h.shape[0], -1)
        elif kind == 'fc':
            # input is already +-1 here (spec places 'sign' before 'fc')
            h = h @ ste_sign(next(p)).T
        elif kind == 'out':
            w, b = next(p), next(p)
            h = h @ ste_sign(w).T + b
    return h, new_state


# --------------------------------------------------------------------------
# Export: fold a trained model into hardware tensors.
# --------------------------------------------------------------------------

def _pad_w(wb):
    """Pad a +-1 [O, K] weight matrix along K to a multiple of 32 with +1
    (non-conducting against the matching -1 activation pads)."""
    o, k = wb.shape
    kp = -(-k // kref.ARRAY_SIZE) * kref.ARRAY_SIZE
    if kp != k:
        wb = jnp.pad(wb, ((0, 0), (0, kp - k)), constant_values=1.0)
    return wb


def hard_sign(x):
    return jnp.where(x >= 0, 1.0, -1.0).astype(jnp.float32)


def export_folded(spec, params, state):
    """Freeze (params, state) into the folded hardware tensors.

    Returns (tensors, names): per matmul a padded +-1 weight `wb{i}`
    (reshaped to [O, C*kh*kw -> padded]), per BN a `scale{i}`/`bias{i}`
    pair, and the final f32 `out.b`. Order matches forward_eval's
    consumption order; the AOT manifest records it.
    """
    p = iter(params)
    st = iter(state)
    out, names = [], []
    mat = 0
    bni = 0

    def emit_w(w):
        nonlocal mat
        wb = _pad_w(hard_sign(w.reshape(w.shape[0], -1)))
        out.append(wb)
        names.append(f'wb{mat}')
        mat += 1

    def emit_bn():
        nonlocal bni
        gamma, beta = next(p), next(p)
        mean, var = next(st), next(st)
        scale, bias = bn_fold(gamma, beta, mean, var)
        out.append(scale)
        names.append(f'scale{bni}')
        out.append(bias)
        names.append(f'bias{bni}')
        bni += 1

    for op in spec:
        kind = op[0]
        if kind == 'conv':
            emit_w(next(p))
        elif kind == 'bn':
            emit_bn()
        elif kind == 'scb':
            emit_w(next(p))
            emit_bn()
            emit_w(next(p))
            emit_bn()
            emit_w(next(p))
            emit_bn()
        elif kind == 'fc':
            emit_w(next(p))
        elif kind == 'out':
            emit_w(next(p))
            out.append(next(p))
            names.append('out.b')
    return out, names


# --------------------------------------------------------------------------
# Hardware-mode (grouped sub-MAC) forward.
# --------------------------------------------------------------------------

def _patches(x, k, stride):
    """im2col: NCHW -> (F=C*kh*kw, B*H'*W') matching OIHW weight reshape."""
    xp = _pad_same(x, k, stride)
    pat = jax.lax.conv_general_dilated_patches(
        xp, (k, k), (stride, stride), 'VALID',
        dimension_numbers=('NCHW', 'OIHW', 'NCHW'))
    b, f, oh, ow = pat.shape
    return pat.transpose(1, 0, 2, 3).reshape(f, b * oh * ow), (b, oh, ow)


def centered_pad(beta):
    """Dummy-cell biasing for a partial tail group (DESIGN.md §4).

    A group with r = beta % 32 < 32 live cells would emit levels in
    [0, r] — far below the peak-16 window every full group lives in, so
    CapMin clipping would wipe it out. Real arrays bias unused cells:
    `p_on` of the 32-r pads are driven conducting (w=+1, x=+1), shifting
    the group's levels to [p_on, p_on + r], centered on 16; the digital
    accumulator subtracts the known 2*p_on offset. Returns
    (p_on, beta_eff) with beta_eff = beta + 2*p_on."""
    r = beta % kref.ARRAY_SIZE
    if r == 0:
        return 0, beta
    p_on = (kref.ARRAY_SIZE - r) // 2
    return p_on, beta + 2 * p_on


def _pad_x_rows(xm):
    """Pad activation rows to a group multiple: the first `p_on` pad
    rows are conducting (+1, dummy bias cells), the rest non-conducting
    (-1). Returns (padded, beta_eff)."""
    k = xm.shape[0]
    kp = -(-k // kref.ARRAY_SIZE) * kref.ARRAY_SIZE
    p_on, beta_eff = centered_pad(k)
    if kp != k:
        ones = jnp.ones((p_on, xm.shape[1]), xm.dtype)
        minus = -jnp.ones((kp - k - p_on, xm.shape[1]), xm.dtype)
        xm = jnp.concatenate([xm, ones, minus], axis=0)
    return xm, beta_eff


class SubMacEngine:
    """Dispatches every binarized matmul of the eval pass.

    engine: 'exact' (plain matmul, ideal circuit), 'jnp' (grouped oracle),
    'pallas' (L1 kernel). `hist=True` additionally accumulates the F_MAC
    level histogram per matmul (clean compute; used by the hist artifact).

    The error model is *per matmul*: `cdf` has shape [n_mat, 33, 33] and
    `vals` [n_mat, 33]. The IF-SNN hardware has one capacitor and one set
    of physical spike times, but the digital decoder is per layer — a
    layer whose reduction length beta only reaches level 9 (e.g. a
    grayscale first conv, beta = 9) keeps its own narrow read-out window
    instead of being wiped out by the peak-centered global window
    (DESIGN.md §CapMin-L).
    """

    def __init__(self, engine, cdf, vals, seed, hist=False):
        self.engine = engine
        self.cdf = cdf
        self.vals = vals
        self.seed = seed
        self.hist = hist
        self.hists = []
        self._mat = 0

    def matmul(self, wb, xm):
        # `xm` arrives unpadded: its row count is the true beta. The K-pad
        # cells are non-conducting, so the digital accumulator subtracts
        # the *true* beta (2*sum_g M_g - beta), not the padded one.
        beta = xm.shape[0]
        xm, beta_eff = _pad_x_rows(xm)
        mat = self._mat
        salt = (mat * _SALT_STRIDE) & 0xFFFFFFFF
        self._mat += 1
        if self.hist:
            self.hists.append(kref.submac_hist(wb, xm))
        if self.engine == 'exact':
            return wb[:, :beta] @ xm[:beta]
        cdf = self.cdf[mat]
        vals = self.vals[mat]
        if self.engine == 'jnp':
            return kref.submac_matmul_ref(
                wb, xm, cdf, vals, self.seed, salt, beta=beta_eff)
        if self.engine == 'pallas':
            return ksub.submac_matmul_pallas(
                wb, xm, cdf, vals, self.seed, salt, beta=beta_eff)
        raise ValueError(self.engine)


def forward_eval(spec, folded, x, eng):
    """Hardware-mode forward. folded: tensors from `export_folded` (same
    order); x: NCHW +-1; eng: SubMacEngine. Returns logits [B, n_cls]."""
    f = iter(folded)

    def affine(y):
        scale, bias = next(f), next(f)
        shape = (1, -1, 1, 1) if y.ndim == 4 else (1, -1)
        return y * scale.reshape(shape) + bias.reshape(shape)

    def conv(h, k, stride):
        wb = next(f)
        xm, (b, oh, ow) = _patches(h, k, stride)
        y = eng.matmul(wb, xm)  # (O, B*oh*ow)
        return y.reshape(-1, b, oh, ow).transpose(1, 0, 2, 3)

    h = x
    for op in spec:
        kind = op[0]
        if kind == 'conv':
            h = conv(h, 3, op[2])
        elif kind == 'mp':
            h = maxpool(h, op[1])
        elif kind == 'bn':
            h = affine(h)
        elif kind == 'sign':
            h = hard_sign(h)
        elif kind == 'scb':
            s = op[2]
            y = hard_sign(affine(conv(h, 3, s)))
            z = affine(conv(y, 3, 1))
            sc = affine(conv(h, 1, s))
            h = hard_sign(z + sc)
        elif kind == 'flatten':
            h = h.reshape(h.shape[0], -1)
        elif kind == 'fc':
            wb = next(f)
            h = eng.matmul(wb, h.T).T
        elif kind == 'out':
            wb = next(f)
            b = None
            y = eng.matmul(wb, h.T).T
            b = next(f)
            h = y + b
    return h


def count_matmuls(spec):
    n = 0
    for op in spec:
        if op[0] in ('conv', 'fc', 'out'):
            n += 1
        elif op[0] == 'scb':
            n += 3
    return n
