"""L1/L2 performance profile (build-time): per-matmul VMEM footprint and
MXU-utilization estimates for the Pallas kernel's block plan, plus HLO
op-count statistics of the lowered eval graphs.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the L1
optimization loop is *structural*: pick block shapes that (a) fit VMEM
with headroom for double buffering, (b) keep the MXU pass shape as full
as the a=32 group structure allows, (c) keep W and the error tables
grid-invariant (resident). This script prints the numbers EXPERIMENTS.md
§Perf cites and fails loudly if a model's plan exceeds the VMEM budget.

Usage: python -m compile.perf [--full]
"""

import argparse
import collections
import re

import jax

from . import configs, nn
from .kernels import submac


VMEM_BUDGET = 16 * 1024 * 1024  # v4/v5e per-core VMEM
VMEM_TARGET = 8 * 1024 * 1024   # leave half for double buffering


def matmul_shapes(cfg):
    """(name, O, K_padded, beta) for every binarized matmul of a model."""
    spec = configs.build_spec(cfg)
    params, state, _, _ = nn.init_model(
        jax.random.PRNGKey(0), spec, cfg['in_shape'])
    folded, names = nn.export_folded(spec, params, state)
    out = []
    for t, n in zip(folded, names):
        if n.startswith('wb'):
            out.append((n, t.shape[0], t.shape[1]))
    return out


def profile_model(name, cfg):
    print(f'\n== {name} — L1 block plan (adaptive block_o, '
          f'block_d={submac.DEFAULT_BLOCK_D}) ==')
    print(f'{"matmul":>8} {"O":>6} {"K_pad":>6} {"groups":>6} '
          f'{"blk_o":>6} {"VMEM/step":>12} {"fits":>5} '
          f'{"MXU util":>9} {"(was)":>7}')
    worst = 0
    for n, o, k in matmul_shapes(cfg):
        bo = submac.adaptive_block_o(o)
        vmem = submac.vmem_footprint_bytes(k, block_o=bo)
        worst = max(worst, vmem)
        mxu = submac.mxu_utilization_estimate(block_o=bo)
        was = submac.mxu_utilization_estimate(block_o=32)
        print(f'{n:>8} {o:>6} {k:>6} {k // 32:>6} {bo:>6} '
              f'{vmem / 1024:>10.1f}KB '
              f'{"yes" if vmem < VMEM_TARGET else "NO":>5} '
              f'{mxu:>9.3f} {was:>7.3f}')
    assert worst < VMEM_TARGET, \
        f'{name}: block plan exceeds VMEM target ({worst} B)'
    return worst


def hlo_op_stats(path):
    """Histogram of HLO opcodes in a lowered artifact (fusion check)."""
    ops = collections.Counter()
    with open(path) as f:
        for line in f:
            m = re.search(r'=\s+\S+\s+([a-z0-9-]+)\(', line)
            if m:
                ops[m.group(1)] += 1
    return ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--full', action='store_true')
    ap.add_argument('--artifacts', default='../artifacts')
    args = ap.parse_args()
    mcfgs = configs.model_configs(full=args.full)
    for name in ('vgg3', 'vgg7', 'resnet18'):
        profile_model(name, mcfgs[name])

    print('\n== L2 HLO op profile (eval graphs) ==')
    import os
    for name in ('vgg3', 'vgg7', 'resnet18'):
        path = os.path.join(args.artifacts, f'{name}_eval.hlo.txt')
        if not os.path.exists(path):
            print(f'{name}: run `make artifacts` first')
            continue
        ops = hlo_op_stats(path)
        total = sum(ops.values())
        top = ', '.join(f'{k}:{v}' for k, v in ops.most_common(6))
        print(f'{name}: {total} ops | {top}')
        # no per-layer host round-trips: a single fused module per model
        assert ops.get('custom-call', 0) == 0, \
            'CPU-incompatible custom call leaked into the artifact'


if __name__ == '__main__':
    main()
