"""AOT driver: lower every L2 graph to HLO *text* + write the manifest.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run once via `make artifacts`; Python never appears on the request path.

Usage:
  python -m compile.aot --out-dir ../artifacts [--models vgg3,vgg7,...]
                        [--full]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import arch, configs, model, nn, train


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir('stablehlo')
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(args):
    """JSON signature entries for a list of (name, ShapeDtypeStruct)."""
    out = []
    for name, s in args:
        dt = {'float32': 'f32', 'uint32': 'u32',
              'int32': 'i32'}[str(s.dtype)]
        out.append({'name': name, 'dtype': dt, 'shape': list(s.shape)})
    return out


def lower_model(name, cfg, out_dir):
    spec = configs.build_spec(cfg)
    in_shape = cfg['in_shape']
    ncls = cfg['n_classes']

    # Trace shapes once with a throwaway init.
    key = jax.random.PRNGKey(0)
    params, state, pnames, snames = nn.init_model(key, spec, in_shape)
    folded, fnames = nn.export_folded(spec, params, state)
    np_, ns_, nf_ = len(params), len(state), len(folded)

    def write(kind, fn, in_named, out_named):
        in_sds = [s for _, s in in_named]
        text = to_hlo_text(jax.jit(fn).lower(*in_sds))
        path = f'{name}_{kind}.hlo.txt'
        with open(os.path.join(out_dir, path), 'w') as f:
            f.write(text)
        return {'kind': kind, 'path': path,
                'inputs': _sig(in_named), 'outputs': _sig(out_named)}

    p_named = [(n, _sds(p.shape)) for n, p in zip(pnames, params)]
    s_named = [(n, _sds(s.shape)) for n, s in zip(snames, state)]
    f_named = [(n, _sds(t.shape)) for n, t in zip(fnames, folded)]
    m_named = [(f'm.{n}', _sds(p.shape)) for n, p in zip(pnames, params)]
    v_named = [(f'v.{n}', _sds(p.shape)) for n, p in zip(pnames, params)]

    tb, eb, hb = cfg['train_batch'], cfg['eval_batch'], cfg['hist_batch']
    x_t = ('x', _sds((tb,) + in_shape))
    y_t = ('y_pm', _sds((tb, ncls)))
    x_e = ('x', _sds((eb,) + in_shape))
    x_h = ('x', _sds((hb,) + in_shape))
    n_mat = nn.count_matmuls(spec)
    cdf_in = ('cdf', _sds((n_mat, 33, 33)))
    vals_in = ('vals', _sds((n_mat, 33)))
    seed_in = ('seed', _sds((), jnp.uint32))

    artifacts = []
    artifacts.append(write(
        'init', model.make_init(spec, in_shape),
        [('key', _sds((2,), jnp.uint32))], p_named + s_named))
    mhl_b = train.margin_for(spec, in_shape)
    artifacts.append(write(
        'train', model.make_train_fn(spec, np_, ns_, mhl_b),
        p_named + s_named + m_named + v_named
        + [('step', _sds(())), ('lr', _sds(())), x_t, y_t],
        p_named + s_named + m_named + v_named + [('loss', _sds(()))]))
    artifacts.append(write(
        'export', model.make_export(spec, np_),
        p_named + s_named, f_named))
    artifacts.append(write(
        'hist', model.make_hist(spec, nf_),
        f_named + [x_h],
        [('fmac', _sds((n_mat, 33))), ('logits', _sds((hb, ncls)))]))
    artifacts.append(write(
        'eval', model.make_eval(spec, nf_, 'jnp'),
        f_named + [x_e, cdf_in, vals_in, seed_in],
        [('logits', _sds((eb, ncls)))]))
    artifacts.append(write(
        'evalp', model.make_eval(spec, nf_, 'pallas'),
        f_named + [x_e, cdf_in, vals_in, seed_in],
        [('logits', _sds((eb, ncls)))]))
    # standalone L1 kernel artifact: single grouped sub-MAC matmul through
    # the Pallas kernel — the bit-exactness bridge for the Rust engine
    # (rust/tests/integration.rs). Shapes: first folded weight x D=64.
    o0, k0 = folded[0].shape
    d0 = 64
    beta0 = params[0].shape[1] * 9 if False else None
    from .kernels import submac as ksub

    def kernel_fn(wb, xb, cdf, vals, seed):
        return (ksub.submac_matmul_pallas(
            wb, xb, cdf, vals, seed, salt=0, beta=k0),)

    artifacts.append(write(
        'kernel', kernel_fn,
        [('wb', _sds((o0, k0))), ('xb', _sds((k0, d0))),
         ('cdf', _sds((33, 33))), ('vals', _sds((33,))),
         ('seed', _sds((), jnp.uint32))],
        [('out', _sds((o0, d0)))]))

    return {
        'arch': cfg['arch'],
        'description': arch.describe(spec),
        'in_shape': list(in_shape),
        'n_classes': ncls,
        'train_batch': tb, 'eval_batch': eb, 'hist_batch': hb,
        'n_params': np_, 'n_state': ns_, 'n_folded': nf_,
        'n_matmuls': n_mat,
        'mhl_b': mhl_b,
        'param_names': pnames, 'state_names': snames,
        'folded_names': fnames,
        'artifacts': artifacts,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--out-dir', default='../artifacts')
    ap.add_argument('--models', default='all')
    ap.add_argument('--full', action='store_true',
                    help="paper-exact widths (Table II); default is the "
                         "CPU-budget scaling (DESIGN.md §6)")
    # kept for Makefile compatibility: --out <file> writes a stamp
    ap.add_argument('--out', default=None)
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    mcfgs = configs.model_configs(full=args.full)
    names = list(mcfgs) if args.models == 'all' else args.models.split(',')

    manifest = {'full': args.full, 'array_size': 32, 'n_levels': 33,
                'models': {}, 'datasets': configs.DATASETS}
    for name in names:
        print(f'[aot] lowering {name} ...', flush=True)
        manifest['models'][name] = lower_model(name, mcfgs[name], out_dir)

    with open(os.path.join(out_dir, 'manifest.json'), 'w') as f:
        json.dump(manifest, f, indent=1)
    print(f'[aot] wrote {out_dir}/manifest.json '
          f'({len(names)} models x 6 artifacts)')
    if args.out:
        with open(args.out, 'w') as f:
            f.write('ok\n')


if __name__ == '__main__':
    main()
