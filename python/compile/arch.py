"""Architecture specs — the paper's Table II, as data.

A spec is a list of ops consumed by `nn.py`/`model.py`:

  ('conv', out_c, stride)   binarized 3x3 conv, SAME padding via explicit
                            -1 padding (binary domain has no zero)
  ('conv1', out_c, stride)  binarized 1x1 conv (ResNet projection)
  ('mp', k)                 max pool k x k
  ('bn',)                   batch norm (folded to a digital affine at export)
  ('sign',)                 binarize activations (+-1, STE in training)
  ('scb', out_c, stride)    ResNet skip-connection block (expanded below)
  ('flatten',)
  ('fc', out_f)             binarized fully connected
  ('out', n_classes)        final binarized FC with f32 bias, emits logits

Widths are configurable (`width` multiplies the per-arch base channel
plan) so the paper's full-size models and CPU-budget models share code.
"""


def _scb(out_c, stride):
    """Skip-connection block: two binarized 3x3 convs with BN, plus a
    projection shortcut (binarized 1x1 conv + BN) when shape changes.
    The branch merge is a digital add of BN-affine outputs, then sign —
    consistent with the paper's 'digital components follow conventional
    designs' accumulation model (DESIGN.md §4)."""
    return [('scb', out_c, stride)]


def vgg3(width=1.0, fc_width=1.0):
    c = max(8, int(64 * width))
    f = max(16, int(2048 * fc_width))
    return ([('conv', c, 1), ('mp', 2), ('bn',), ('sign',),
             ('conv', c, 1), ('mp', 2), ('bn',), ('sign',),
             ('flatten',),
             ('fc', f), ('bn',), ('sign',)]
            + [('out', 10)])


def vgg7(width=1.0, fc_width=1.0):
    c1 = max(8, int(128 * width))
    c2 = max(8, int(256 * width))
    c3 = max(8, int(512 * width))
    f = max(16, int(1024 * fc_width))
    return ([('conv', c1, 1), ('bn',), ('sign',),
             ('conv', c1, 1), ('mp', 2), ('bn',), ('sign',),
             ('conv', c2, 1), ('bn',), ('sign',),
             ('conv', c2, 1), ('mp', 2), ('bn',), ('sign',),
             ('conv', c3, 1), ('bn',), ('sign',),
             ('conv', c3, 1), ('mp', 2), ('bn',), ('sign',),
             ('flatten',),
             ('fc', f), ('bn',), ('sign',)]
            + [('out', 10)])


def resnet18(width=1.0):
    b = max(8, int(64 * width))
    return ([('conv', b, 1), ('bn',), ('sign',)]
            + _scb(b, 1)
            + _scb(2 * b, 2)
            + _scb(4 * b, 2)
            + [('mp', 2)]
            + _scb(8 * b, 1)
            + [('mp', 4), ('flatten',)]
            + [('out', 10)])


ARCH_BUILDERS = {
    'vgg3': vgg3,
    'vgg7': vgg7,
    'resnet18': resnet18,
}


def describe(spec):
    """One-line per-op description (Table II regeneration)."""
    rows = []
    for op in spec:
        if op[0] == 'conv':
            rows.append(f'C{op[1]}' + (f'/s{op[2]}' if op[2] != 1 else ''))
        elif op[0] == 'conv1':
            rows.append(f'C1x1-{op[1]}')
        elif op[0] == 'mp':
            rows.append(f'MP{op[1]}')
        elif op[0] == 'scb':
            rows.append(f'SCB{op[1]}' + (f'/s{op[2]}' if op[2] != 1 else ''))
        elif op[0] == 'fc':
            rows.append(f'FC{op[1]}')
        elif op[0] == 'out':
            rows.append(f'FC{op[1]}')
        elif op[0] in ('bn', 'sign', 'flatten'):
            continue
    return ' -> '.join(rows)
