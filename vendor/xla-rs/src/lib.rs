// Stub crate: only compiled when the `xla` feature of `capmin` is
// enabled without the real bridge vendored in place of this directory.
compile_error!(
    "the `xla` feature needs the real PJRT bridge: replace vendor/xla-rs \
     with a symlink to /opt/xla-example/xla-rs (`make vendor`; see \
     DESIGN.md §8)"
);
