//! Quickstart for `capmin serve` (DESIGN.md §12): drive a serving
//! process over its newline-delimited JSON protocol — an operating
//! point, a micro-batched inference, server stats, then a graceful
//! shutdown.
//!
//!   # self-contained (spawns an in-process server on a free port):
//!   cargo run --release --example serve_client
//!
//!   # against a running `capmin serve`:
//!   capmin serve --addr 127.0.0.1:7878 --dataset fashion_syn --quick &
//!   cargo run --release --example serve_client -- 127.0.0.1:7878
//!
//! With an address argument the example also sends the shutdown (so a
//! CI smoke can start a server, run this, and wait for a clean exit).

use std::net::SocketAddr;

use anyhow::Result;
use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::serve::{server, Backoff, Client, ServeOptions};
use capmin::util::table::si;

fn main() -> Result<()> {
    // either connect to the given server, or spawn one of our own
    let external: Option<SocketAddr> = match std::env::args().nth(1) {
        Some(a) => Some(
            a.parse()
                .map_err(|e| anyhow::anyhow!("bad addr `{a}`: {e}"))?,
        ),
        None => None,
    };
    let mut own = None;
    let addr = match external {
        Some(a) => a,
        None => {
            let mut cfg = ExperimentConfig::default();
            cfg.backend = "native".into();
            cfg.mc_samples = 200;
            cfg.hist_limit = 64;
            cfg.run_dir = std::env::temp_dir()
                .join("capmin_serve_example")
                .to_str()
                .unwrap()
                .into();
            let opts =
                ServeOptions::new("127.0.0.1:0".parse().unwrap());
            let srv = server::spawn(cfg, opts)?;
            let addr = srv.addr();
            println!("spawned an in-process server on {addr}");
            own = Some(srv);
            addr
        }
    };

    // the shared jittered-backoff policy (DESIGN.md §16) — generous
    // enough to ride out a `capmin serve &` still binding its socket
    // (the CI smoke races exactly that)
    let mut client = Client::connect_backoff(
        addr,
        Backoff {
            attempts: 16,
            base_ms: 50,
            cap_ms: 2000,
        },
    )?;

    // 1. a codesign query — answered from the warm session's caches
    //    after the first hit
    let ds = Dataset::FashionSyn.spec();
    let p = client.point(ds.name, 14, 0.02, 0, false)?;
    println!(
        "point {}@k=14: C = {}, GRT = {}, window [{}, {}]",
        ds.name,
        si(p.req("c").as_f64(), "F"),
        si(p.req("grt").as_f64(), "s"),
        p.req("window").req("q_lo").as_usize(),
        p.req("window").req("q_hi").as_usize(),
    );

    // 2. inference at that operating point: two +-1 samples; had other
    //    clients hit the server right now, the batcher would coalesce
    //    us with them — without changing a bit of this reply
    let mut rng = capmin::util::rng::Rng::new(7);
    let xs: Vec<Vec<f32>> = (0..2)
        .map(|_| (0..ds.pixels()).map(|_| rng.pm1(0.5)).collect())
        .collect();
    let reply = client.infer(ds.name, 14, 0.02, 0, 1, &xs)?;
    let classes: Vec<usize> = reply
        .req("classes")
        .as_arr()
        .iter()
        .map(|c| c.as_usize())
        .collect();
    println!("infer: {} samples -> classes {:?}", xs.len(), classes);

    // 3. server stats: counters, micro-batch and latency histograms,
    //    and the (startup-fixed) thread crews
    let st = client.stats()?;
    let stats = st.req("stats");
    println!(
        "stats: {} infers over {} micro-batches | workers {} | \
         solve crew {} | infer crew {}",
        stats.req("requests").req("infer").as_usize(),
        stats.req("infer").req("micro_batches").as_usize(),
        stats.req("server").req("workers").as_usize(),
        stats.req("server").req("session_pool_workers").as_usize(),
        stats.req("server").req("infer_pool_workers").as_usize(),
    );

    // 4. graceful shutdown: the server drains in-flight work first
    client.shutdown()?;
    println!("shutdown acknowledged (drain started)");
    if let Some(srv) = own {
        srv.join()?;
        println!("in-process server drained and exited cleanly");
    }
    Ok(())
}
