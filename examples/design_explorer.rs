//! Hardware design-space explorer: sweep the variation strength sigma
//! and the CapMin parameter k over the analog substrate alone (no model
//! needed) and print the operating-point map a circuit designer would
//! use to pick (C, k, phi).
//!
//!   cargo run --release --example design_explorer [-- --sigma-max 0.08]

use capmin::analog::capacitor::{CapacitorModel, CapacitorSolver};
use capmin::analog::montecarlo::MonteCarlo;
use capmin::analog::neuron::SpikeTimeSet;
use capmin::analog::params::AnalogParams;
use capmin::capmin::capmin_v::capmin_v;
use capmin::util::cli::Args;
use capmin::util::rng::Rng;
use capmin::util::table::{si, Table};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let sigma_max = args.f64_or("sigma-max", 0.08);
    let samples = args.usize_or("mc-samples", 1000);

    println!("== operating map: min diagonal P(correct read-out) ==");
    println!("(window centered on the level-16 peak; 2 GHz clock)\n");

    let ks = [32usize, 24, 20, 16, 14, 12, 10, 8];
    let sigmas: Vec<f64> = (1..=8)
        .map(|i| sigma_max * i as f64 / 8.0)
        .collect();
    let mut t = Table::new(
        &std::iter::once("k \\ sigma".to_string())
            .chain(sigmas.iter().map(|s| format!("{s:.3}")))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>(),
    );
    for &k in &ks {
        let lo = (17 - k / 2).max(1);
        let hi = (lo + k - 1).min(32);
        let mut row = vec![format!("{k} [{lo},{hi}]")];
        for &sigma in &sigmas {
            let p = AnalogParams::paper_calibrated().with_sigma(sigma);
            let c = CapacitorSolver::new(p, CapacitorModel::Physics)
                .size_for_window(lo, hi);
            let set = SpikeTimeSet::new(&p, c, (lo..=hi).collect());
            let mc = MonteCarlo::new(p).with_samples(samples);
            let pm = mc.pmap(&set, &mut Rng::new(1));
            let min_diag = pm
                .diag()
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min);
            row.push(format!("{min_diag:.2}"));
        }
        t.row(row);
    }
    println!("{}", t.render());

    println!("== CapMin-V repair at sigma = {sigma_max:.3} ==");
    let p = AnalogParams::paper_calibrated().with_sigma(sigma_max);
    let solver = CapacitorSolver::new(p, CapacitorModel::Physics);
    let (lo, hi) = (9usize, 24usize); // k = 16 start, paper Sec. IV-C
    let c = solver.size_for_window(lo, hi);
    let set = SpikeTimeSet::new(&p, c, (lo..=hi).collect());
    let mc = MonteCarlo::new(p).with_samples(samples);
    let mut t = Table::new(&[
        "phi", "k_eff", "surviving levels", "min diag", "C",
    ]);
    for phi in [0usize, 1, 2, 4, 6, 8] {
        let pm = mc.pmap(&set, &mut Rng::new(2));
        let res = capmin_v(pm, phi);
        let set_v = SpikeTimeSet::new(&p, c, res.levels.clone());
        let pm_v = mc.pmap(&set_v, &mut Rng::new(3));
        let min_diag = pm_v
            .diag()
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        t.row(vec![
            phi.to_string(),
            (16 - phi).to_string(),
            format!("{:?}", res.levels),
            format!("{min_diag:.3}"),
            si(c, "F"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "(capacitor stays at the k=16 size; merges trade levels for \
         read-out margin — the paper's CapMin-V story)"
    );
}
