//! Quickstart: the whole CapMin flow through the `DesignSession` API,
//! in about a minute on one CPU core.
//!
//!   cargo run --release --example quickstart
//!
//! One session owns the runtime, the run store and the config; typed
//! operating-point queries do the rest (train -> fold -> F_MAC ->
//! CapMin window -> capacitor sizing -> error model -> accuracy), with
//! every stage cached so a second run answers from `runs/points/`.

use anyhow::Result;
use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::session::{DesignSession, OperatingPointSpec};
use capmin::util::table::si;

fn main() -> Result<()> {
    // quickstart scale: small training budget, temp run directory
    let mut cfg = ExperimentConfig::default();
    cfg.train_steps = 80;
    cfg.train_limit = 512;
    cfg.hist_limit = 128;
    cfg.eval_limit = 64;
    cfg.mc_samples = 500;
    cfg.run_dir = std::env::temp_dir()
        .join("capmin_quickstart")
        .to_str()
        .unwrap()
        .into();

    // the 10-line core (mirrored in DESIGN.md §3):
    let session = DesignSession::builder().config(cfg).build()?;
    let ds = Dataset::FashionSyn;
    let points = session.query_many(&[
        // baseline: all 32 spike times, no variation
        OperatingPointSpec::new(ds, 32, 0.0, 0).with_eval(1, 1),
        // CapMin at k = 14, clean
        OperatingPointSpec::new(ds, 14, 0.0, 0).with_eval(1, 1),
        // CapMin at k = 14 under 2% current variation
        OperatingPointSpec::new(ds, 14, 0.02, 0).with_eval(1, 1),
    ])?;
    let (hw32, hw14, hw14v) = (&points[0], &points[1], &points[2]);

    println!(
        "capacitor: baseline {} -> CapMin(k=14) {}  ({:.2}x smaller)",
        si(hw32.c, "F"),
        si(hw14.c, "F"),
        hw32.c / hw14.c
    );
    println!(
        "peak window at k=14: [{}, {}] covering {:.3} of all sub-MACs",
        hw14.peak_window().q_lo,
        hw14.peak_window().q_hi,
        hw14.peak_window().coverage
    );
    println!(
        "accuracy: k=32 {:.1}% | k=14 clean {:.1}% | k=14 under \
         2% current variation {:.1}%",
        100.0 * hw32.accuracy.unwrap(),
        100.0 * hw14.accuracy.unwrap(),
        100.0 * hw14v.accuracy.unwrap()
    );

    // repeat queries are memoized: no second training / MC run
    let again = session
        .query(&OperatingPointSpec::new(ds, 14, 0.0, 0).with_eval(1, 1))?;
    assert_eq!(again.accuracy, hw14.accuracy);
    let s = session.stats();
    println!(
        "session stats: {} queries, {} hits, {} solves (points cached \
         under runs/points/)",
        s.queries,
        s.hits(),
        s.solves
    );
    println!("quickstart OK");
    Ok(())
}
