//! Quickstart: the whole CapMin flow on the tiny model, in under a
//! minute on one CPU core.
//!
//!   cargo run --release --example quickstart
//!
//! Steps: synthesize data -> train a tiny BNN via the AOT train-step
//! artifact -> fold to hardware tensors -> extract F_MAC -> pick a
//! CapMin window -> size the capacitor -> evaluate accuracy with the
//! error model injected at sub-MAC granularity.

use anyhow::Result;
use capmin::coordinator::config::ExperimentConfig;
use capmin::coordinator::evaluator::Evaluator;
use capmin::coordinator::histogrammer::Histogrammer;
use capmin::coordinator::pipeline::Pipeline;
use capmin::coordinator::trainer::Trainer;
use capmin::data::synth::Dataset;
use capmin::data::{Loader, Split};
use capmin::runtime::Runtime;
use capmin::util::table::si;

fn main() -> Result<()> {
    let rt = Runtime::new()?;
    let model = "vgg3_tiny";
    let spec = Dataset::FashionSyn.spec();
    let mi = rt.manifest.model(model).clone();
    println!("model: {} ({})", model, mi.description);

    // 1. train via the AOT train-step artifact (Rust owns the loop)
    let trainer = Trainer::new(&rt);
    let mut loader =
        Loader::new(spec.clone(), Split::Train, mi.train_batch, 512, 1);
    let trained = trainer.train(
        model, &mut loader, 80, 1e-2, 60, 42,
        &mut |step, loss| {
            if step % 20 == 0 {
                println!("  step {step:>3}  loss {loss:.4}");
            }
        },
    )?;

    // 2. fold BN + binarize into the IF-SNN hardware tensors
    let folded = trainer.export(&trained)?;
    println!("folded {} hardware tensors", folded.len());

    // 3. extract F_MAC (the SW statistics CapMin feeds on)
    let hist = Histogrammer::new(&rt);
    let hres = hist.extract_dataset(
        model, &folded, spec.clone(), 128, 7)?;
    println!(
        "F_MAC over {} samples (clean train-acc {:.1}%), peak level {}",
        hres.n_samples,
        100.0 * hres.accuracy,
        (0..33).max_by_key(|&m| hres.sum.counts[m]).unwrap()
    );

    // 4. CapMin at k = 14 + capacitor sizing + error models
    let mut cfg = ExperimentConfig::default();
    cfg.mc_samples = 500;
    cfg.run_dir = std::env::temp_dir()
        .join("capmin_quickstart")
        .to_str()
        .unwrap()
        .into();
    let pipe = Pipeline::new(&rt, cfg)?;
    let hw32 = pipe.hw_config(&hres.per_matmul, 32, 0.0, 0);
    let hw14 = pipe.hw_config(&hres.per_matmul, 14, 0.0, 0);
    let hw14v = pipe.hw_config(&hres.per_matmul, 14, 0.02, 0);
    println!(
        "capacitor: baseline {} -> CapMin(k=14) {}  ({:.2}x smaller)",
        si(hw32.c, "F"),
        si(hw14.c, "F"),
        hw32.c / hw14.c
    );

    // 5. hardware-mode accuracy (error model injected per sub-MAC)
    let ev = Evaluator::new(&rt, "eval");
    let a32 = ev.accuracy(model, &folded, spec.clone(), &hw32.ems, 64, 1)?;
    let a14 = ev.accuracy(model, &folded, spec.clone(), &hw14.ems, 64, 1)?;
    let a14v =
        ev.accuracy(model, &folded, spec.clone(), &hw14v.ems, 64, 1)?;
    println!("accuracy: k=32 {:.1}% | k=14 clean {:.1}% | k=14 under \
              2% current variation {:.1}%",
             100.0 * a32, 100.0 * a14, 100.0 * a14v);
    println!("quickstart OK");
    Ok(())
}
