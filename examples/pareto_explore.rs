//! Quickstart for the design-space explorer (DESIGN.md §13): sweep
//! CapMin windows through a `capmin serve` process, price each one via
//! the `cost` field every `point` reply now carries, and compute the
//! accuracy-free hardware frontier client-side with
//! `capmin::util::pareto`.
//!
//!   # self-contained (spawns an in-process server on a free port):
//!   cargo run --release --example pareto_explore
//!
//!   # against a running `capmin serve`:
//!   capmin serve --addr 127.0.0.1:7878 --dataset fashion_syn --quick &
//!   cargo run --release --example pareto_explore -- 127.0.0.1:7878
//!
//! For the full accuracy/energy/area/latency frontiers (CapMin vs
//! CapMin-V, deduplicated against the fig8 sweep), run the plan
//! instead: `capmin suite --plans pareto --emit md`.

use std::net::SocketAddr;

use anyhow::Result;
use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::serve::{server, Backoff, Client, ServeOptions};
use capmin::util::pareto::non_dominated;
use capmin::util::table::si;

fn main() -> Result<()> {
    // either connect to the given server, or spawn one of our own
    let external: Option<SocketAddr> = match std::env::args().nth(1) {
        Some(a) => Some(
            a.parse()
                .map_err(|e| anyhow::anyhow!("bad addr `{a}`: {e}"))?,
        ),
        None => None,
    };
    let mut own = None;
    let addr = match external {
        Some(a) => a,
        None => {
            let mut cfg = ExperimentConfig::default();
            cfg.backend = "native".into();
            cfg.mc_samples = 200;
            cfg.hist_limit = 64;
            cfg.run_dir = std::env::temp_dir()
                .join("capmin_pareto_example")
                .to_str()
                .unwrap()
                .into();
            let opts =
                ServeOptions::new("127.0.0.1:0".parse().unwrap());
            let srv = server::spawn(cfg, opts)?;
            let addr = srv.addr();
            println!("spawned an in-process server on {addr}");
            own = Some(srv);
            addr
        }
    };

    // the shared jittered-backoff policy (DESIGN.md §16), generous
    // enough to ride out a `capmin serve &` still binding its socket
    let mut client = Client::connect_backoff(
        addr,
        Backoff {
            attempts: 16,
            base_ms: 50,
            cap_ms: 2000,
        },
    )?;

    // 1. sweep k and collect each point's typed cost vector — the
    //    server prices every reply from the shared cost model, so a
    //    client never reimplements the formulas
    let ds = Dataset::FashionSyn.spec();
    let ks = [32usize, 24, 20, 16, 14, 12, 10];
    let mut costs = Vec::new();
    for &k in &ks {
        let (_, cost) = client.point_cost(ds.name, k, 0.02, 0, false)?;
        println!(
            "k={k:>2}: C = {}  E/pass = {}  area = {}  latency = {}",
            si(cost.c, "F"),
            si(cost.energy, "J"),
            si(cost.area, "m2"),
            si(cost.latency, "s"),
        );
        costs.push((k, cost));
    }

    // 2. the hardware-only frontier (energy, area, latency — all
    //    minimized). With accuracy excluded every objective improves
    //    monotonically as k shrinks, so the smallest window should be
    //    the lone survivor — a quick sanity check of the cost model.
    let vals: Vec<Vec<f64>> = costs
        .iter()
        .map(|(_, cv)| vec![cv.energy, cv.area, cv.latency])
        .collect();
    let front = non_dominated(&vals);
    let survivors: Vec<usize> =
        front.iter().map(|&i| costs[i].0).collect();
    println!(
        "hardware-only frontier (energy/area/latency): k in {:?}",
        survivors
    );

    // 3. graceful shutdown
    client.shutdown()?;
    println!("shutdown acknowledged (drain started)");
    if let Some(srv) = own {
        srv.join()?;
        println!("in-process server drained and exited cleanly");
    }
    Ok(())
}
