//! End-to-end validation driver (EXPERIMENTS.md §End-to-end): proves all
//! three layers compose on a real workload.
//!
//!   cargo run --release --example end_to_end [-- --steps 300]
//!
//! Trains the full vgg3 BNN on the fashion_syn benchmark through the AOT
//! train-step artifact (L2 fwd/bwd + Adam, Rust loop), logs the loss
//! curve, folds to hardware tensors, extracts F_MAC, queries the CapMin
//! k-sweep operating points with variation and CapMin-V from one
//! `DesignSession`, evaluates them through BOTH eval engines (jnp
//! oracle and the L1 Pallas kernel), and prints the paper-shaped
//! summary.

use anyhow::Result;
use capmin::coordinator::config::ExperimentConfig;
use capmin::coordinator::evaluator::Evaluator;
use capmin::data::synth::Dataset;
use capmin::session::{DesignSession, OperatingPointSpec};
use capmin::util::cli::Args;
use capmin::util::table::{si, Table};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let mut cfg = ExperimentConfig::from_args(&args)?;
    if args.get("steps").is_none() {
        cfg.train_steps = 300;
    }
    cfg.run_dir = args.str_or("run-dir", "runs/end_to_end");
    let session = DesignSession::builder().config(cfg).build()?;
    let ds = Dataset::FashionSyn;
    let spec = ds.spec();

    let t0 = std::time::Instant::now();
    // 1-2. train + fold (cached if a previous run exists)
    let folded = session.folded(ds)?;
    // loss curve from the run store
    if let Ok(ts) = session.store().load_tensors(&format!(
        "{}_losses.capt",
        spec.name
    )) {
        let losses = &ts[0].data;
        println!("loss curve ({} steps):", losses.len());
        let stride = (losses.len() / 10).max(1);
        for (i, l) in losses.iter().enumerate() {
            if i % stride == 0 || i + 1 == losses.len() {
                println!("  step {:>4}  loss {l:.4}", i + 1);
            }
        }
    }

    // 3. F_MAC
    let (_per_fmac, sum) = session.fmac(ds)?;
    println!(
        "F_MAC: {} sub-MACs, dynamic range {:.1e} (paper: 1e5..1e7)",
        sum.total(),
        sum.dynamic_range()
    );

    // 4. k-sweep through BOTH engines at three operating points —
    // hardware-only queries here; the engines are driven explicitly
    // below because the Pallas interpret path needs a smaller limit
    let sigma = session.config().sigma_rel;
    let mut table = Table::new(&[
        "k", "C (physics)", "engine", "clean", "+variation", "CapMin-V",
    ]);
    for &k in &[32usize, 14, 8] {
        let hw_clean =
            session.query(&OperatingPointSpec::new(ds, k, 0.0, 0))?;
        let hw_var =
            session.query(&OperatingPointSpec::new(ds, k, sigma, 0))?;
        let phi = 16usize.saturating_sub(k);
        let hw_v = if k < 16 {
            Some(session.query(&OperatingPointSpec::new(
                ds, 16, sigma, phi,
            ))?)
        } else {
            None
        };
        for engine in ["eval", "evalp"] {
            // Pallas interpret mode is slow: run it on the smaller point
            if engine == "evalp" && k != 14 {
                continue;
            }
            let limit = if engine == "evalp" {
                session.config().eval_limit.min(32)
            } else {
                session.config().eval_limit
            };
            let ev = Evaluator::new(session.runtime()?, engine);
            let a_clean = ev.accuracy(
                spec.model, folded.as_slice(), spec.clone(),
                &hw_clean.ems, limit, 1)?;
            let a_var = ev.accuracy(
                spec.model, folded.as_slice(), spec.clone(),
                &hw_var.ems, limit, 100)?;
            let a_v = match &hw_v {
                Some(hw) => format!(
                    "{:.1}%",
                    100.0 * ev.accuracy(
                        spec.model, folded.as_slice(), spec.clone(),
                        &hw.ems, limit, 200)?
                ),
                None => "-".into(),
            };
            table.row(vec![
                k.to_string(),
                si(hw_clean.c, "F"),
                engine.into(),
                format!("{:.1}%", 100.0 * a_clean),
                format!("{:.1}%", 100.0 * a_var),
                a_v,
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "end-to-end OK in {:.1?} (engines agree bit-exactly by \
         construction; see cargo test --test integration)",
        t0.elapsed()
    );
    Ok(())
}
