//! End-to-end validation driver (EXPERIMENTS.md §End-to-end): proves
//! the layers compose on a real workload, on whatever inference
//! backend the build and machine provide (DESIGN.md §9).
//!
//!   cargo run --release --example end_to_end [-- --steps 300]
//!   cargo run --release --example end_to_end -- --backend native
//!
//! On an `xla` build with artifacts this trains the full vgg3 BNN on
//! the fashion_syn benchmark through the AOT train-step artifact (L2
//! fwd/bwd + Adam, Rust loop), logs the loss curve, folds to hardware
//! tensors; on a native-only build it starts from cached trained
//! weights (or the flagged untrained fallback). Either way it extracts
//! F_MAC, queries the CapMin k-sweep operating points with variation
//! and CapMin-V from one `DesignSession`, evaluates them through the
//! resolved backend, and prints the paper-shaped summary.

use anyhow::Result;
use capmin::backend::InferenceBackend;
use capmin::coordinator::config::ExperimentConfig;
use capmin::data::synth::Dataset;
use capmin::session::{DesignSession, OperatingPointSpec};
use capmin::util::cli::Args;
use capmin::util::table::{si, Table};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let mut cfg = ExperimentConfig::from_args(&args)?;
    if args.get("steps").is_none() {
        cfg.train_steps = 300;
    }
    cfg.run_dir = args.str_or("run-dir", "runs/end_to_end");
    let session = DesignSession::builder().config(cfg).build()?;
    let ds = Dataset::FashionSyn;
    let spec = ds.spec();

    let t0 = std::time::Instant::now();
    // 1-2. train + fold (cached if a previous run exists; untrained
    // fallback on native-only builds with a cold store)
    let folded = session.folded(ds)?;
    println!(
        "folded model: {} tensors via {} backend ({} threads)",
        folded.len(),
        session.backend_name(),
        session.threads()
    );
    // loss curve from the run store
    if let Ok(ts) = session.store().load_tensors(&format!(
        "{}_losses.capt",
        spec.name
    )) {
        let losses = &ts[0].data;
        println!("loss curve ({} steps):", losses.len());
        let stride = (losses.len() / 10).max(1);
        for (i, l) in losses.iter().enumerate() {
            if i % stride == 0 || i + 1 == losses.len() {
                println!("  step {:>4}  loss {l:.4}", i + 1);
            }
        }
    }

    // 3. F_MAC
    let (_per_fmac, sum) = session.fmac(ds)?;
    println!(
        "F_MAC: {} sub-MACs, dynamic range {:.1e} (paper: 1e5..1e7)",
        sum.total(),
        sum.dynamic_range()
    );

    // 4. k-sweep at three operating points through the resolved
    // backend — hardware-only queries, then explicit accuracy calls so
    // the same error models are reused across rows
    let sigma = session.config().sigma_rel;
    let backend = session.backend()?;
    let mut table = Table::new(&[
        "k", "C (physics)", "backend", "clean", "+variation", "CapMin-V",
    ]);
    for &k in &[32usize, 14, 8] {
        let hw_clean =
            session.query(&OperatingPointSpec::new(ds, k, 0.0, 0))?;
        let hw_var =
            session.query(&OperatingPointSpec::new(ds, k, sigma, 0))?;
        let phi = 16usize.saturating_sub(k);
        let hw_v = if k < 16 {
            Some(session.query(&OperatingPointSpec::new(
                ds, 16, sigma, phi,
            ))?)
        } else {
            None
        };
        let limit = session.config().eval_limit;
        let a_clean = backend.accuracy(
            spec.model, &folded, spec.clone(), &hw_clean.ems, limit, 1,
        )?;
        let a_var = backend.accuracy(
            spec.model, &folded, spec.clone(), &hw_var.ems, limit, 100,
        )?;
        let a_v = match &hw_v {
            Some(hw) => format!(
                "{:.1}%",
                100.0
                    * backend.accuracy(
                        spec.model,
                        &folded,
                        spec.clone(),
                        &hw.ems,
                        limit,
                        200
                    )?
            ),
            None => "-".into(),
        };
        table.row(vec![
            k.to_string(),
            si(hw_clean.c, "F"),
            backend.name().into(),
            format!("{:.1}%", 100.0 * a_clean),
            format!("{:.1}%", 100.0 * a_var),
            a_v,
        ]);
    }
    println!("{}", table.render());
    println!(
        "end-to-end OK in {:.1?} (backends agree bit-exactly by \
         construction; see cargo test --test backend)",
        t0.elapsed()
    );
    Ok(())
}
